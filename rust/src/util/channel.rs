//! Bounded MPMC channel with blocking backpressure (Mutex + Condvar).
//!
//! This is the transport of the in-process stream broker and the engines'
//! operator pipelines: `send` blocks when the queue is full — exactly the
//! backpressure semantics a Kafka producer / Flink network stack exhibits —
//! and `recv` blocks when it is empty.  Closing is cooperative: any sender
//! or the owner may `close()`; receivers drain remaining items first.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Shared<T> {
    q: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct State<T> {
    buf: VecDeque<T>,
    closed: bool,
    senders: usize,
}

/// Sending half (cloneable).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half (cloneable — MPMC).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

// Manual Debug without a `T: Debug` bound (payloads need not be printable).
impl<T> std::fmt::Debug for Sender<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Sender").finish_non_exhaustive()
    }
}

impl<T> std::fmt::Debug for Receiver<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Receiver").finish_non_exhaustive()
    }
}

/// Error returned when sending on a closed channel.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Error for `try_recv`.
#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    Empty,
    Closed,
}

/// Create a bounded channel with capacity `cap` (>= 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        q: Mutex::new(State { buf: VecDeque::with_capacity(cap.max(1)), closed: false, senders: 1 }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        cap: cap.max(1),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.q.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.q.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            st.closed = true;
            drop(st);
            self.shared.not_empty.notify_all();
            self.shared.not_full.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Sender<T> {
    /// Blocking send; returns the value if the channel is closed.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError(value));
            }
            if st.buf.len() < self.shared.cap {
                st.buf.push_back(value);
                drop(st);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; `Err` carries the value back on full/closed.
    pub fn try_send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.q.lock().unwrap();
        if st.closed || st.buf.len() >= self.shared.cap {
            return Err(SendError(value));
        }
        st.buf.push_back(value);
        drop(st);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Close the channel (receivers drain what is buffered).
    pub fn close(&self) {
        let mut st = self.shared.q.lock().unwrap();
        st.closed = true;
        drop(st);
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }

    /// Number of buffered items (diagnostics).
    pub fn len(&self) -> usize {
        self.shared.q.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` when closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let mut st = self.shared.q.lock().unwrap();
        if let Some(v) = st.buf.pop_front() {
            drop(st);
            self.shared.not_full.notify_one();
            return Ok(v);
        }
        if st.closed {
            Err(TryRecvError::Closed)
        } else {
            Err(TryRecvError::Empty)
        }
    }

    /// Drain everything currently buffered without blocking.
    pub fn drain(&self) -> Vec<T> {
        let mut st = self.shared.q.lock().unwrap();
        let out: Vec<T> = st.buf.drain(..).collect();
        drop(st);
        self.shared.not_full.notify_all();
        out
    }

    pub fn len(&self) -> usize {
        self.shared.q.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once closed and drained.
    pub fn is_terminated(&self) -> bool {
        let st = self.shared.q.lock().unwrap();
        st.closed && st.buf.is_empty()
    }
}

impl<T> Iterator for Receiver<T> {
    type Item = T;
    fn next(&mut self) -> Option<T> {
        self.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn send_recv_fifo() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
    }

    #[test]
    fn close_drains_then_none() {
        let (tx, rx) = bounded(8);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.close();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
        assert!(tx.send(3).is_err());
    }

    #[test]
    fn drop_all_senders_closes() {
        let (tx, rx) = bounded::<u32>(2);
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(7).unwrap();
        drop(tx2);
        assert_eq!(rx.recv(), Some(7));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn try_send_full() {
        let (tx, _rx) = bounded(1);
        tx.try_send(1).unwrap();
        assert_eq!(tx.try_send(2), Err(SendError(2)));
    }

    #[test]
    fn try_recv_empty_vs_closed() {
        let (tx, rx) = bounded::<u32>(1);
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        tx.close();
        assert_eq!(rx.try_recv(), Err(TryRecvError::Closed));
    }

    #[test]
    fn backpressure_blocks_sender() {
        let (tx, rx) = bounded(2);
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = sent.clone();
        let h = std::thread::spawn(move || {
            for i in 0..10 {
                tx.send(i).unwrap();
                sent2.fetch_add(1, Ordering::SeqCst);
            }
        });
        std::thread::sleep(Duration::from_millis(50));
        // Sender must be stuck at capacity.
        let s = sent.load(Ordering::SeqCst);
        assert!(s <= 3, "sender ran ahead: {s}");
        let all: Vec<_> = (0..10).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        h.join().unwrap();
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(16);
        let n_producers = 4;
        let per = 1000;
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        drop(tx);
        let seen = Arc::new(Mutex::new(Vec::new()));
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            let seen = seen.clone();
            consumers.push(std::thread::spawn(move || {
                while let Some(v) = rx.recv() {
                    seen.lock().unwrap().push(v);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for c in consumers {
            c.join().unwrap();
        }
        let mut all = seen.lock().unwrap().clone();
        all.sort();
        assert_eq!(all, (0..n_producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn drain_returns_buffered() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.drain(), vec![0, 1, 2, 3, 4]);
        assert!(rx.is_empty());
    }
}
