//! Lock-free bounded single-producer/single-consumer ring.
//!
//! The data plane of the ingest pool: each worker gets one ring carrying
//! item chunks coordinator→worker and one carrying drained buffers back
//! worker→coordinator, so steady-state ingest crosses threads without a
//! mutex, a condvar wakeup, or a heap allocation.  The classic
//! Lamport/FastFlow design: monotonically increasing head/tail indices, the
//! producer owns `tail`, the consumer owns `head`, and each side reads the
//! other's index with `Acquire` against its own `Release` store.
//!
//! Blocking behavior is spin-then-yield-then-nap (no condvar — the point is
//! that the hot path never takes a lock); the control plane stays on
//! [`crate::util::channel`], whose blocking semantics fit rendezvous
//! messages.  Either side closing (or dropping) wakes the other via the
//! `closed` flag.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Error returned by [`SpscSender::send`] when the consumer is gone.
#[derive(Debug, PartialEq, Eq)]
pub struct RingSendError<T>(pub T);

struct Ring<T> {
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    cap: usize,
    /// Next index to pop (owned by the consumer).
    head: AtomicUsize,
    /// Next index to push (owned by the producer).
    tail: AtomicUsize,
    closed: AtomicBool,
}

// SAFETY: the ring hands each `T` from exactly one thread to exactly one
// other thread, so `Ring<T>` only needs `T: Send`.  Auto-impls are blocked
// by the `UnsafeCell` slots; moving the whole ring between threads is fine
// because a slot's contents are only touched by whichever side currently
// owns the index range it sits in.
unsafe impl<T: Send> Send for Ring<T> {}
// SAFETY: `&Ring` is shared between exactly two threads (the non-cloneable
// sender and receiver halves).  Slots are never aliased mutably: the
// producer writes only slots in `[tail, head+cap)` and the consumer reads
// only `[head, tail)`, and each side publishes its index with `Release`
// before the other side's `Acquire` load can include the slot in its range
// — the head/tail ordering partitions slot ownership between the sides.
unsafe impl<T: Send> Sync for Ring<T> {}

impl<T> Drop for Ring<T> {
    fn drop(&mut self) {
        // `&mut self` proves both sides are gone; drop whatever is queued.
        let head = *self.head.get_mut();
        let tail = *self.tail.get_mut();
        for i in head..tail {
            let slot = self.slots[i % self.cap].get();
            // SAFETY: `[head, tail)` is exactly the set of slots the
            // producer initialized (via `write`) and the consumer has not
            // yet moved out (via `assume_init_read`), so each is a live `T`
            // we own exclusively here (`&mut self`).
            unsafe { (*slot).assume_init_drop() };
        }
    }
}

/// Producing half (not cloneable — single producer).
pub struct SpscSender<T> {
    ring: Arc<Ring<T>>,
}

/// Consuming half (not cloneable — single consumer).
pub struct SpscReceiver<T> {
    ring: Arc<Ring<T>>,
}

// Manual Debug (no `T: Debug` bound — chunks carrying samples need not be
// printable): capacity plus the approximate occupancy/closed state.
impl<T> std::fmt::Debug for SpscSender<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpscSender")
            .field("cap", &self.ring.cap)
            .field("len", &self.len())
            .finish_non_exhaustive()
    }
}

impl<T> std::fmt::Debug for SpscReceiver<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // ordering: approximate occupancy snapshot for diagnostics only.
        let tail = self.ring.tail.load(Ordering::Relaxed);
        // ordering: same diagnostics-only snapshot.
        let len = tail.wrapping_sub(self.ring.head.load(Ordering::Relaxed));
        f.debug_struct("SpscReceiver")
            .field("cap", &self.ring.cap)
            .field("len", &len)
            .finish_non_exhaustive()
    }
}

/// Create a bounded SPSC ring with capacity `cap` (>= 1).
pub fn spsc<T>(cap: usize) -> (SpscSender<T>, SpscReceiver<T>) {
    let cap = cap.max(1);
    let slots: Box<[UnsafeCell<MaybeUninit<T>>]> =
        (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
    let ring = Arc::new(Ring {
        slots,
        cap,
        head: AtomicUsize::new(0),
        tail: AtomicUsize::new(0),
        closed: AtomicBool::new(false),
    });
    (SpscSender { ring: ring.clone() }, SpscReceiver { ring })
}

/// Progressive backoff for the blocking paths: spin briefly (the common
/// hand-off latency is tens of ns), then yield the core, then nap with the
/// nap growing geometrically toward ~1 ms — a long-idle side wakes only
/// ~1k times/sec instead of hot-polling, and the counter resets to
/// spinning the moment work arrives.  Shared with the ingest workers' poll
/// loop.
#[inline]
pub(crate) fn backoff(round: u32) {
    if round < 64 {
        std::hint::spin_loop();
    } else if round < 256 {
        std::thread::yield_now();
    } else {
        let exp = ((round - 256) / 32).min(4);
        std::thread::sleep(std::time::Duration::from_micros(50u64 << exp));
    }
}

impl<T> SpscSender<T> {
    /// Non-blocking push; gives the value back when the ring is full or the
    /// consumer is gone.
    // lint: hot-path — per-chunk push on the ingest data plane
    pub fn try_send(&self, value: T) -> Result<(), T> {
        let ring = &*self.ring;
        // ordering: `closed` is an advisory flag, not a data hand-off; a
        // stale read only delays the failure by one call, it never loses or
        // duplicates an item.
        if ring.closed.load(Ordering::Relaxed) {
            return Err(value);
        }
        // ordering: the producer is the only writer of `tail`, so reading
        // its own index needs no synchronization.
        let tail = ring.tail.load(Ordering::Relaxed);
        // ordering: Acquire pairs with the consumer's Release store of
        // `head` — once we observe head advanced past a slot, the
        // consumer's `assume_init_read` of that slot happens-before our
        // re-`write` of it.
        let head = ring.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) >= ring.cap {
            return Err(value);
        }
        // SAFETY: `tail - head < cap` proves slot `tail % cap` is outside
        // the consumer's live range `[head, tail)`: it is either never
        // initialized or already moved out (the Acquire above synchronizes
        // with the read), so overwriting the `MaybeUninit` cannot leak or
        // race.
        unsafe { (*ring.slots[tail % ring.cap].get()).write(value) };
        // ordering: Release publishes the slot write above to the
        // consumer's Acquire load of `tail` before the slot becomes part of
        // its readable range.
        ring.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Blocking push with backpressure; `Err` when the consumer is gone.
    pub fn send(&self, value: T) -> Result<(), RingSendError<T>> {
        let mut value = value;
        let mut round = 0u32;
        loop {
            match self.try_send(value) {
                Ok(()) => return Ok(()),
                Err(v) => {
                    // ordering: advisory close check in a retry loop; a
                    // stale value costs one more backoff round at most.
                    if self.ring.closed.load(Ordering::Relaxed) {
                        return Err(RingSendError(v));
                    }
                    value = v;
                }
            }
            backoff(round);
            round = round.saturating_add(1);
        }
    }

    /// Mark the ring closed (the receiver drains what is buffered).
    pub fn close(&self) {
        self.ring.closed.store(true, Ordering::Release);
    }

    /// Buffered item count (approximate under concurrency).
    pub fn len(&self) -> usize {
        let ring = &*self.ring;
        // ordering: own-index read (producer owns `tail`); the result is
        // documented as approximate, no slot access depends on it.
        ring.tail.load(Ordering::Relaxed).wrapping_sub(ring.head.load(Ordering::Acquire))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for SpscSender<T> {
    fn drop(&mut self) {
        self.close();
    }
}

impl<T> SpscReceiver<T> {
    /// Non-blocking pop; `None` when the ring is currently empty.
    // lint: hot-path — per-chunk pop on the ingest data plane
    pub fn try_recv(&self) -> Option<T> {
        let ring = &*self.ring;
        // ordering: the consumer is the only writer of `head`, so reading
        // its own index needs no synchronization.
        let head = ring.head.load(Ordering::Relaxed);
        // ordering: Acquire pairs with the producer's Release store of
        // `tail`, making the slot `write` visible before the slot enters
        // our readable range `[head, tail)`.
        let tail = ring.tail.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        // SAFETY: `head != tail` puts slot `head % cap` inside `[head,
        // tail)`, which the producer initialized with `write` before its
        // Release store of `tail` (synchronized by the Acquire above), and
        // which we have not yet moved out of — so it holds a live `T`.
        let value = unsafe { (*ring.slots[head % ring.cap].get()).assume_init_read() };
        // ordering: Release pairs with the producer's Acquire load of
        // `head` — our move-out above happens-before the producer reuses
        // the slot.
        ring.head.store(head.wrapping_add(1), Ordering::Release);
        Some(value)
    }

    /// Blocking pop; `None` once the ring is closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut round = 0u32;
        loop {
            if let Some(v) = self.try_recv() {
                return Some(v);
            }
            if self.ring.closed.load(Ordering::Acquire) {
                // Re-check after observing the close so a final item pushed
                // just before closing is not lost.
                return self.try_recv();
            }
            backoff(round);
            round = round.saturating_add(1);
        }
    }

    /// True once closed with nothing left to drain.
    pub fn is_terminated(&self) -> bool {
        self.ring.closed.load(Ordering::Acquire)
            // ordering: own-index read (consumer owns `head`); the tail
            // Acquire pairs with the producer's final Release store.
            && self.ring.head.load(Ordering::Relaxed)
                == self.ring.tail.load(Ordering::Acquire)
    }

    /// Mark the ring closed from the consumer side (producer's next send
    /// fails instead of blocking forever).
    pub fn close(&self) {
        self.ring.closed.store(true, Ordering::Release);
    }
}

impl<T> Drop for SpscReceiver<T> {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_roundtrip() {
        let (tx, rx) = spsc(8);
        for i in 0..5 {
            tx.try_send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.try_recv(), Some(i));
        }
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn full_ring_rejects() {
        let (tx, rx) = spsc(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(3));
        assert_eq!(rx.try_recv(), Some(1));
        tx.try_send(3).unwrap();
        assert_eq!(tx.len(), 2);
    }

    #[test]
    fn wraparound_many_times() {
        let (tx, rx) = spsc(4);
        for i in 0..1000 {
            tx.try_send(i).unwrap();
            assert_eq!(rx.try_recv(), Some(i));
        }
    }

    #[test]
    fn close_drains_then_none() {
        let (tx, rx) = spsc(8);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        tx.close();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
        assert!(rx.is_terminated());
    }

    #[test]
    fn sender_drop_closes() {
        let (tx, rx) = spsc(4);
        tx.try_send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(7));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn receiver_drop_fails_sends() {
        let (tx, rx) = spsc(4);
        drop(rx);
        assert!(tx.send(1).is_err());
        assert_eq!(tx.try_send(2), Err(2));
    }

    #[test]
    fn cross_thread_conservation_with_backpressure() {
        let (tx, rx) = spsc(4);
        let n = 100_000usize;
        let received = std::thread::scope(|scope| {
            let consumer = scope.spawn(move || {
                let mut got = Vec::with_capacity(n);
                while let Some(v) = rx.recv() {
                    got.push(v);
                }
                got
            });
            for i in 0..n {
                tx.send(i).unwrap();
            }
            drop(tx); // close
            consumer.join().unwrap()
        });
        assert_eq!(received, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn queued_items_dropped_on_ring_drop() {
        // Drop both halves with items still queued: their destructors run
        // (observable through Arc strong counts).
        let marker = Arc::new(());
        let (tx, rx) = spsc(8);
        for _ in 0..5 {
            tx.try_send(marker.clone()).unwrap();
        }
        assert_eq!(Arc::strong_count(&marker), 6);
        drop(tx);
        drop(rx);
        assert_eq!(Arc::strong_count(&marker), 1);
    }
}
