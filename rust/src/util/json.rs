//! Minimal JSON parser + writer (offline build — no serde).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! f64.  Used for `artifacts/manifest.json` and for machine-readable
//! benchmark reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| "bad hex digit".to_string())?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|e| e.to_string())?;
                    out.push_str(s);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

/// Convenience builder for object values.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
          "num_strata": 16, "pad_id": -1,
          "outputs": [{"name": "partials", "shape": [16, 3]}],
          "variants": [{"n_items": 1024, "num_strata": 16, "file": "a.hlo.txt"}],
          "jax_version": "0.8.2"
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("num_strata").unwrap().as_i64(), Some(16));
        assert_eq!(v.get("pad_id").unwrap().as_i64(), Some(-1));
        let outs = v.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs[0].get("name").unwrap().as_str(), Some("partials"));
        let shape = outs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[1].as_i64(), Some(3));
    }

    #[test]
    fn roundtrip() {
        let v = obj(vec![
            ("a", Value::Num(1.5)),
            ("b", Value::Arr(vec![Value::Bool(true), Value::Null])),
            ("c", Value::Str("x\"y\n".into())),
        ]);
        let s = v.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn rejects_unterminated() {
        assert!(parse("{\"a\": ").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("[1, 2").is_err());
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(parse("-12.5e2").unwrap().as_f64(), Some(-1250.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""A\t\\ süß""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\\ süß"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
    }

    #[test]
    fn integer_formatting_compact() {
        assert_eq!(Value::Num(16.0).to_string(), "16");
        assert_eq!(Value::Num(1.25).to_string(), "1.25");
    }
}
