//! Case-study datasets (paper §6).
//!
//! The originals (670 GB CAIDA traces; the DEBS'15 NYC taxi dataset) are not
//! redistributable, so these are synthetic generators that preserve the
//! properties the experiments exercise: the stratification (protocol /
//! borough), the strata skew, and the heavy-tailed value distributions.
//! DESIGN.md §2 documents the substitutions.

pub mod caida;
pub mod taxi;

pub use caida::{CaidaConfig, CaidaSourcesConfig};
pub use taxi::TaxiConfig;
