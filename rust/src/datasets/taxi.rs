//! Synthetic NYC-taxi-like ride trace (paper §6.3; DEBS'15 grand challenge).
//!
//! The paper maps each ride's start coordinates to one of New York's
//! boroughs and measures the average trip distance per borough per sliding
//! window.  This generator reproduces:
//!
//! * six borough strata with the strong Manhattan skew of the real data;
//! * log-normal trip distances whose medians differ per borough (short
//!   intra-Manhattan hops vs long Staten Island / airport trips);
//! * item value = trip distance in miles, stratum = borough.

use crate::core::{Item, StratumId};
use crate::util::rng::Rng;

/// Borough strata.
pub const MANHATTAN: StratumId = 0;
pub const BROOKLYN: StratumId = 1;
pub const QUEENS: StratumId = 2;
pub const BRONX: StratumId = 3;
pub const STATEN_ISLAND: StratumId = 4;
pub const OTHER: StratumId = 5;

pub const BOROUGHS: [&str; 6] =
    ["manhattan", "brooklyn", "queens", "bronx", "staten-island", "other"];

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct TaxiConfig {
    /// Rides per second of virtual time.
    pub rides_per_sec: f64,
    /// Borough mix — the 2013 dataset is overwhelmingly Manhattan-origin.
    pub mix: [f64; 6],
    pub seed: u64,
}

impl Default for TaxiConfig {
    fn default() -> Self {
        Self {
            rides_per_sec: 15_000.0,
            mix: [0.88, 0.06, 0.04, 0.012, 0.003, 0.005],
            seed: 2013,
        }
    }
}

/// (log-mu, log-sigma) of trip distance per borough.
const DIST_PARAMS: [(f64, f64); 6] = [
    (0.6, 0.6),  // manhattan: median ~1.8 mi
    (1.1, 0.6),  // brooklyn: ~3 mi
    (1.6, 0.7),  // queens: ~5 mi (airports)
    (1.3, 0.6),  // bronx: ~3.7 mi
    (2.0, 0.5),  // staten island: ~7.4 mi
    (1.5, 0.9),  // other: diffuse
];

impl TaxiConfig {
    /// Generate `duration_ms` of trace, sorted by event time.
    pub fn generate(&self, duration_ms: u64) -> Vec<Item> {
        let mut rng = Rng::seed_from_u64(self.seed);
        let n = (self.rides_per_sec * duration_ms as f64 / 1000.0) as usize;
        let mut items = Vec::with_capacity(n);
        for _ in 0..n {
            let ts = rng.range_u64(0, duration_ms.max(1));
            let b = rng.categorical(&self.mix);
            let (mu, sigma) = DIST_PARAMS[b];
            let miles = rng.log_normal(mu, sigma).min(100.0);
            items.push(Item::new(b as StratumId, miles, ts));
        }
        items.sort_by_key(|i| i.ts);
        items
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manhattan_dominates() {
        let items = TaxiConfig::default().generate(5_000);
        let n = items.len() as f64;
        let manhattan =
            items.iter().filter(|i| i.stratum == MANHATTAN).count() as f64 / n;
        assert!((manhattan - 0.88).abs() < 0.02, "manhattan share {manhattan}");
        // all six boroughs appear
        for b in 0..6u16 {
            assert!(items.iter().any(|i| i.stratum == b), "borough {b} missing");
        }
    }

    #[test]
    fn distances_ordered_by_borough() {
        let items = TaxiConfig::default().generate(20_000);
        let mean = |b: StratumId| {
            let v: Vec<f64> =
                items.iter().filter(|i| i.stratum == b).map(|i| i.value).collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(mean(MANHATTAN) < mean(BROOKLYN));
        assert!(mean(BROOKLYN) < mean(QUEENS));
        assert!(mean(QUEENS) < mean(STATEN_ISLAND));
    }

    #[test]
    fn distances_positive_and_bounded() {
        let items = TaxiConfig::default().generate(2_000);
        for it in &items {
            assert!(it.value > 0.0 && it.value <= 100.0);
        }
    }

    #[test]
    fn deterministic_and_sorted() {
        let a = TaxiConfig::default().generate(1_000);
        let b = TaxiConfig::default().generate(1_000);
        assert!(a.iter().zip(&b).all(|(x, y)| x == y));
        assert!(a.windows(2).all(|w| w[0].ts <= w[1].ts));
    }
}
