//! Synthetic CAIDA-like NetFlow trace (paper §6.2).
//!
//! The paper converts CAIDA Chicago backbone captures to NetFlow records and
//! measures total TCP/UDP/ICMP traffic per sliding window.  This generator
//! reproduces the relevant structure:
//!
//! * three protocol strata — TCP ≈ 85%, UDP ≈ 12%, ICMP ≈ 3% of flows
//!   (typical backbone mix);
//! * heavy-tailed flow sizes (log-normal body, matching the well-known
//!   skew of backbone flow-size distributions), ICMP flows tiny and
//!   near-constant;
//! * item value = flow bytes, stratum = protocol.

use crate::core::{Item, StratumId};
use crate::util::rng::Rng;

/// Protocol strata.
pub const TCP: StratumId = 0;
pub const UDP: StratumId = 1;
pub const ICMP: StratumId = 2;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct CaidaConfig {
    /// Flows per second of virtual time.
    pub flows_per_sec: f64,
    /// Protocol mix (TCP, UDP, ICMP) — normalized internally.
    pub mix: [f64; 3],
    pub seed: u64,
}

impl Default for CaidaConfig {
    fn default() -> Self {
        Self { flows_per_sec: 20_000.0, mix: [0.85, 0.12, 0.03], seed: 2015 }
    }
}

impl CaidaConfig {
    /// Generate `duration_ms` of trace, sorted by event time.
    pub fn generate(&self, duration_ms: u64) -> Vec<Item> {
        let mut rng = Rng::seed_from_u64(self.seed);
        let total: f64 = self.mix.iter().sum();
        let n = (self.flows_per_sec * duration_ms as f64 / 1000.0) as usize;
        let mut items = Vec::with_capacity(n);
        for _ in 0..n {
            let ts = rng.range_u64(0, duration_ms.max(1));
            let proto = rng.categorical(&self.mix);
            let bytes = match proto as u16 {
                TCP => {
                    // log-normal body: median ~ 1 KB, heavy tail into MBs.
                    // (sigma chosen so windows of ~10^5 flows keep a stable
                    // tail share — the real trace's windows hold millions of
                    // flows, which self-average far more.)
                    rng.log_normal(6.9, 1.5).min(1e7)
                }
                UDP => {
                    // mostly small datagram flows, median ~ 300 B
                    rng.log_normal(5.7, 1.2).min(1e6)
                }
                _ => {
                    // ICMP: tiny near-constant probes
                    64.0 + rng.range_f64(0.0, 64.0)
                }
            };
            let _ = total;
            items.push(Item::new(proto as StratumId, bytes, ts));
        }
        items.sort_by_key(|i| i.ts);
        items
    }
}

/// CAIDA-style *per-source* trace for heavy-hitter workloads: flows are
/// stratified by source ("source IP" bucketed into at most
/// [`crate::core::MAX_STRATA`] strata) with Zipf-distributed popularity —
/// the canonical skew of backbone source activity — and log-normal flow
/// sizes.  Used by `Query::TopK` demos/tests: the head sources dominate, so
/// top-k must recover them at any reasonable sampling fraction.
#[derive(Debug, Clone)]
pub struct CaidaSourcesConfig {
    /// Number of distinct sources (strata); clamped to `MAX_STRATA`.
    pub sources: usize,
    /// Zipf exponent of source popularity (≥ ~1 → strong skew).
    pub exponent: f64,
    /// Flows per second of virtual time.
    pub flows_per_sec: f64,
    pub seed: u64,
}

impl Default for CaidaSourcesConfig {
    fn default() -> Self {
        Self {
            sources: crate::core::MAX_STRATA,
            exponent: 1.2,
            flows_per_sec: 20_000.0,
            seed: 2016,
        }
    }
}

impl CaidaSourcesConfig {
    /// Normalized Zipf popularity of each source (descending by rank).
    pub fn popularity(&self) -> Vec<f64> {
        let n = self.sources.clamp(1, crate::core::MAX_STRATA);
        let raw: Vec<f64> = (0..n).map(|i| 1.0 / (1.0 + i as f64).powf(self.exponent)).collect();
        let total: f64 = raw.iter().sum();
        raw.into_iter().map(|w| w / total).collect()
    }

    /// Generate `duration_ms` of trace, sorted by event time.
    pub fn generate(&self, duration_ms: u64) -> Vec<Item> {
        let mut rng = Rng::seed_from_u64(self.seed);
        let pop = self.popularity();
        let n = (self.flows_per_sec * duration_ms as f64 / 1000.0) as usize;
        let mut items = Vec::with_capacity(n);
        for _ in 0..n {
            let ts = rng.range_u64(0, duration_ms.max(1));
            let src = rng.categorical(&pop);
            let bytes = rng.log_normal(6.9, 1.5).min(1e7);
            items.push(Item::new(src as StratumId, bytes, ts));
        }
        items.sort_by_key(|i| i.ts);
        items
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sources_skew_is_zipf_ordered() {
        let cfg = CaidaSourcesConfig::default();
        let items = cfg.generate(10_000);
        let mut counts = vec![0usize; crate::core::MAX_STRATA];
        for it in &items {
            counts[it.stratum as usize] += 1;
        }
        // the head source strictly dominates, and popularity decays by rank
        assert!(counts[0] > counts[1] && counts[1] > counts[2]);
        assert!(counts[0] as f64 > 3.0 * counts[8] as f64);
        let pop = cfg.popularity();
        assert!((pop.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sources_deterministic_and_sorted() {
        let cfg = CaidaSourcesConfig { flows_per_sec: 2_000.0, ..Default::default() };
        let a = cfg.generate(3_000);
        let b = cfg.generate(3_000);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x == y));
        assert!(a.windows(2).all(|w| w[0].ts <= w[1].ts));
    }

    #[test]
    fn mix_shares_hold() {
        let items = CaidaConfig::default().generate(10_000);
        let n = items.len() as f64;
        let share = |p: StratumId| items.iter().filter(|i| i.stratum == p).count() as f64 / n;
        assert!((share(TCP) - 0.85).abs() < 0.02, "tcp {}", share(TCP));
        assert!((share(UDP) - 0.12).abs() < 0.02, "udp {}", share(UDP));
        assert!((share(ICMP) - 0.03).abs() < 0.01, "icmp {}", share(ICMP));
    }

    #[test]
    fn flow_sizes_heavy_tailed() {
        let items = CaidaConfig::default().generate(5_000);
        let tcp: Vec<f64> = items.iter().filter(|i| i.stratum == TCP).map(|i| i.value).collect();
        let mut sorted = tcp.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let mean = tcp.iter().sum::<f64>() / tcp.len() as f64;
        // heavy tail: mean far above median
        assert!(mean > 2.0 * median, "mean {mean} median {median}");
    }

    #[test]
    fn icmp_values_small() {
        let items = CaidaConfig::default().generate(5_000);
        for it in items.iter().filter(|i| i.stratum == ICMP) {
            assert!(it.value >= 64.0 && it.value <= 128.0);
        }
    }

    #[test]
    fn sorted_and_sized() {
        let cfg = CaidaConfig { flows_per_sec: 1000.0, ..Default::default() };
        let items = cfg.generate(4_000);
        assert!((items.len() as f64 - 4000.0).abs() < 200.0);
        assert!(items.windows(2).all(|w| w[0].ts <= w[1].ts));
    }

    #[test]
    fn deterministic() {
        let a = CaidaConfig::default().generate(1_000);
        let b = CaidaConfig::default().generate(1_000);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x == y));
    }
}
