//! Benchmark harness: regenerates every table/figure of the paper's
//! evaluation (§5 microbenchmarks, §6 case studies).  Each `figN_*`
//! function runs the corresponding experiment and returns an ASCII table
//! with the same rows/series the paper plots; the `benches/` binaries and
//! the CLI (`streamapprox bench --figure ...`) are thin wrappers.
//!
//! The six evaluated systems map onto (engine, sampler) pairs:
//!
//! | paper name            | engine    | sampler |
//! |-----------------------|-----------|---------|
//! | Spark-StreamApprox    | batched   | OASRS   |
//! | Flink-StreamApprox    | pipelined | OASRS   |
//! | Spark-based SRS       | batched   | SRS     |
//! | Spark-based STS       | batched   | STS     |
//! | native Spark          | batched   | none    |
//! | native Flink          | pipelined | none    |

pub mod figures;

use crate::budget::QueryBudget;
use crate::core::Item;
use crate::engine::EngineKind;
use crate::metrics::{summarize, RunSummary};
use crate::pipeline::PipelineBuilder;
use crate::query::Query;
use crate::runtime::{Backend, ComputeHandle, ComputeService};
use crate::sampling::SamplerKind;
use crate::window::WindowConfig;

/// The six systems of the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    SparkApprox,
    FlinkApprox,
    SparkSrs,
    SparkSts,
    NativeSpark,
    NativeFlink,
}

impl System {
    pub const ALL: [System; 6] = [
        System::SparkApprox,
        System::FlinkApprox,
        System::SparkSrs,
        System::SparkSts,
        System::NativeSpark,
        System::NativeFlink,
    ];

    /// The four sampled systems (Figs. 6a, 7b, 9c, 10c).
    pub const SAMPLED: [System; 4] =
        [System::SparkApprox, System::FlinkApprox, System::SparkSrs, System::SparkSts];

    /// The three Spark-based sampled systems (Figs. 5c, 8, 11).
    pub const SPARK_SAMPLED: [System; 3] =
        [System::SparkApprox, System::SparkSrs, System::SparkSts];

    pub fn label(self) -> &'static str {
        match self {
            System::SparkApprox => "spark-streamapprox",
            System::FlinkApprox => "flink-streamapprox",
            System::SparkSrs => "spark-srs",
            System::SparkSts => "spark-sts",
            System::NativeSpark => "native-spark",
            System::NativeFlink => "native-flink",
        }
    }

    pub fn engine(self) -> EngineKind {
        match self {
            System::FlinkApprox | System::NativeFlink => EngineKind::Pipelined,
            _ => EngineKind::Batched,
        }
    }

    pub fn sampler(self) -> SamplerKind {
        match self {
            System::SparkApprox | System::FlinkApprox => SamplerKind::Oasrs,
            System::SparkSrs => SamplerKind::Srs,
            System::SparkSts => SamplerKind::Sts,
            System::NativeSpark | System::NativeFlink => SamplerKind::None,
        }
    }
}

/// Experiment scale preset.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    /// Virtual duration of each run (ms).
    pub duration_ms: u64,
    /// Repeats per configuration (the paper averages 10 runs).
    pub repeats: usize,
    /// Workers per system.
    pub workers: usize,
}

impl Scale {
    /// Fast preset for `cargo bench` smoke runs and CI.
    pub fn quick() -> Self {
        Self { duration_ms: 30_000, repeats: 2, workers: 2 }
    }

    /// Full preset for the recorded EXPERIMENTS.md numbers.
    pub fn full() -> Self {
        Self { duration_ms: 60_000, repeats: 3, workers: 2 }
    }
}

/// Shared harness context: one compute service reused by every pipeline so
/// the XLA artifacts compile once.
pub struct Ctx {
    service: ComputeService,
    pub scale: Scale,
}

impl std::fmt::Debug for Ctx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ctx")
            .field("service", &self.service)
            .field("scale", &self.scale)
            .finish()
    }
}

impl Ctx {
    /// XLA backend when artifacts are present, else the native executor.
    pub fn auto(scale: Scale) -> Self {
        let service = match ComputeService::start(Backend::Xla, None) {
            Ok(svc) => svc,
            Err(e) => {
                eprintln!("note: XLA backend unavailable ({e}); using native executor");
                ComputeService::native()
            }
        };
        let ctx = Self { service, scale };
        ctx.warm_up();
        ctx
    }

    /// Execute each artifact variant once so first-run JIT/alloc costs don't
    /// land inside the first measurement.
    fn warm_up(&self) {
        use crate::runtime::WindowInput;
        let h = self.handle();
        for n in [1024usize, 4096, 16384] {
            let mut wi = WindowInput::default();
            wi.ids = vec![0; n];
            wi.values = vec![1.0; n];
            wi.c[0] = n as f64;
            wi.n_cap = [n as f64; crate::error::estimator::K];
            let _ = h.aggregate(wi);
        }
    }

    pub fn native(scale: Scale) -> Self {
        Self { service: ComputeService::native(), scale }
    }

    pub fn handle(&self) -> ComputeHandle {
        self.service.handle()
    }

    pub fn backend(&self) -> Backend {
        self.service.handle().backend()
    }
}

/// Per-stage latency table from a metrics snapshot (or a run's snapshot
/// delta): one row per latency histogram, with p50/p95/p99/max in µs.
/// Shared by the CLI's `run --metrics` output and the CI perf-smoke job
/// summary; empty-count series are skipped so a linear-query run does not
/// print all-zero sketch rows.
pub fn stage_latency_table(snap: &crate::obs::MetricsSnapshot) -> crate::util::table::Table {
    let mut t = crate::util::table::Table::new(
        "per-stage latency (us)",
        &["stage", "count", "p50", "p95", "p99", "max"],
    );
    let us = |ns: u64| format!("{:.1}", ns as f64 / 1e3);
    for (series, h) in &snap.hists {
        if h.count == 0 {
            continue;
        }
        t.row(vec![
            series.clone(),
            h.count.to_string(),
            us(h.quantile(0.5)),
            us(h.quantile(0.95)),
            us(h.quantile(0.99)),
            us(h.max),
        ]);
    }
    t
}

/// One measured configuration.
#[derive(Debug)]
pub struct Measurement {
    pub system: System,
    pub summary: RunSummary,
}

/// Run `system` over a shared trace and summarize across repeats
/// (`ctx.scale.workers` workers).
#[allow(clippy::too_many_arguments)]
pub fn run_system(
    ctx: &Ctx,
    system: System,
    items: &[Item],
    window: WindowConfig,
    query: Query,
    fraction: f64,
    batch_interval_ms: u64,
    track_exact: bool,
) -> Measurement {
    run_system_workers(
        ctx,
        system,
        items,
        window,
        query,
        fraction,
        batch_interval_ms,
        track_exact,
        ctx.scale.workers,
    )
}

/// [`run_system`] with an explicit worker count (scalability sweeps).
#[allow(clippy::too_many_arguments)]
pub fn run_system_workers(
    ctx: &Ctx,
    system: System,
    items: &[Item],
    window: WindowConfig,
    query: Query,
    fraction: f64,
    batch_interval_ms: u64,
    track_exact: bool,
    workers: usize,
) -> Measurement {
    let mut reports = Vec::new();
    for rep in 0..ctx.scale.repeats {
        let pipeline = PipelineBuilder::new()
            .engine(system.engine())
            .sampler(system.sampler())
            .budget(QueryBudget::SamplingFraction(fraction))
            .query(query.clone())
            .window(window)
            .batch_interval_ms(batch_interval_ms)
            .workers(workers)
            .track_exact(track_exact)
            .seed(42 + rep as u64)
            .build_with_handle(ctx.handle());
        reports.push(pipeline.run_items(items).expect("pipeline run"));
    }
    Measurement { system, summary: summarize(&reports) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_mapping() {
        assert_eq!(System::SparkApprox.engine(), EngineKind::Batched);
        assert_eq!(System::SparkApprox.sampler(), SamplerKind::Oasrs);
        assert_eq!(System::FlinkApprox.engine(), EngineKind::Pipelined);
        assert_eq!(System::NativeFlink.sampler(), SamplerKind::None);
        assert_eq!(System::ALL.len(), 6);
    }

    #[test]
    fn run_system_produces_summary() {
        let ctx = Ctx::native(Scale { duration_ms: 4_000, repeats: 2, workers: 1 });
        let items = crate::stream::StreamGenerator::new(
            &crate::stream::StreamConfig::gaussian_micro(100.0, 1),
        )
        .take_until(4_000);
        let m = run_system(
            &ctx,
            System::SparkApprox,
            &items,
            WindowConfig::new(2_000, 1_000),
            Query::Sum,
            0.5,
            500,
            true,
        );
        assert_eq!(m.summary.runs, 2);
        assert!(m.summary.throughput > 0.0);
        assert!(m.summary.accuracy_loss < 0.2);
    }
}
