//! One function per paper figure.  Each returns the rendered table(s); the
//! caller (bench binary / CLI) prints them and EXPERIMENTS.md records the
//! paper-vs-measured comparison.

use crate::core::Item;
use crate::datasets::{CaidaConfig, TaxiConfig};
use crate::metrics::fraction_for_accuracy;
use crate::query::Query;
use crate::stream::{StreamConfig, StreamGenerator};
use crate::util::table::{fmt_pct, fmt_throughput, Table};
use crate::window::WindowConfig;

use super::{run_system, Ctx, System};

/// Sampling fractions swept by the paper (10%–90%).
pub const FRACTIONS: [f64; 5] = [0.1, 0.2, 0.4, 0.6, 0.8];

fn micro_trace(ctx: &Ctx, rate_c: f64, seed: u64) -> Vec<Item> {
    StreamGenerator::new(&StreamConfig::gaussian_micro(rate_c, seed))
        .take_until(ctx.scale.duration_ms)
}

fn window_default() -> WindowConfig {
    WindowConfig::paper_default()
}

/// Fig. 5a — peak throughput vs sampling fraction, all six systems
/// (Gaussian microbenchmark).
pub fn fig5a(ctx: &Ctx) -> Table {
    let items = micro_trace(ctx, 1000.0, 50);
    let mut t = Table::new(
        "Fig 5a: peak throughput (items/s) vs sampling fraction — Gaussian micro",
        &["system", "10%", "20%", "40%", "60%", "80%", "native(100%)"],
    );
    for sys in [System::SparkApprox, System::FlinkApprox, System::SparkSrs, System::SparkSts] {
        let mut row = vec![sys.label().to_string()];
        for &f in &FRACTIONS {
            let m = run_system(ctx, sys, &items, window_default(), Query::Sum, f, 500, false);
            row.push(fmt_throughput(m.summary.throughput));
        }
        row.push("-".into());
        t.row(row);
    }
    for sys in [System::NativeSpark, System::NativeFlink] {
        let m = run_system(ctx, sys, &items, window_default(), Query::Sum, 1.0, 500, false);
        let mut row = vec![sys.label().to_string()];
        row.extend(std::iter::repeat("-".to_string()).take(5));
        row.push(fmt_throughput(m.summary.throughput));
        t.row(row);
    }
    t
}

/// Fig. 5b — accuracy loss vs sampling fraction.
pub fn fig5b(ctx: &Ctx) -> Table {
    let items = micro_trace(ctx, 1000.0, 51);
    let mut t = Table::new(
        "Fig 5b: accuracy loss vs sampling fraction — Gaussian micro",
        &["system", "10%", "20%", "40%", "60%", "80%"],
    );
    for sys in [System::SparkApprox, System::FlinkApprox, System::SparkSrs, System::SparkSts] {
        let mut row = vec![sys.label().to_string()];
        for &f in &FRACTIONS {
            let m = run_system(ctx, sys, &items, window_default(), Query::Sum, f, 500, true);
            row.push(fmt_pct(m.summary.accuracy_loss));
        }
        t.row(row);
    }
    t
}

/// Fig. 5c — throughput vs batch interval (Spark-based systems, 60%).
pub fn fig5c(ctx: &Ctx) -> Table {
    let items = micro_trace(ctx, 1000.0, 52);
    let mut t = Table::new(
        "Fig 5c: peak throughput (items/s) vs batch interval — Spark systems @60%",
        &["system", "250ms", "500ms", "1000ms"],
    );
    for sys in System::SPARK_SAMPLED {
        let mut row = vec![sys.label().to_string()];
        for &bi in &[250u64, 500, 1000] {
            let m = run_system(ctx, sys, &items, window_default(), Query::Sum, 0.6, bi, false);
            row.push(fmt_throughput(m.summary.throughput));
        }
        t.row(row);
    }
    t
}

/// Fig. 6a — accuracy loss vs arrival rate of sub-stream C (60%).
pub fn fig6a(ctx: &Ctx) -> Table {
    let mut t = Table::new(
        "Fig 6a: accuracy loss vs arrival rate of sub-stream C @60%",
        &["system", "100/s", "1000/s", "4000/s", "8000/s"],
    );
    let rates = [100.0, 1000.0, 4000.0, 8000.0];
    let traces: Vec<Vec<Item>> =
        rates.iter().map(|&rc| micro_trace(ctx, rc, 53)).collect();
    for sys in System::SAMPLED {
        let mut row = vec![sys.label().to_string()];
        for items in &traces {
            let m = run_system(ctx, sys, items, window_default(), Query::Sum, 0.6, 500, true);
            row.push(fmt_pct(m.summary.accuracy_loss));
        }
        t.row(row);
    }
    t
}

/// Fig. 6b/6c — throughput + accuracy vs window size (rates 8000/2000/100).
pub fn fig6bc(ctx: &Ctx) -> (Table, Table) {
    let items = micro_trace(ctx, 100.0, 54);
    let sizes: [(u64, u64); 3] = [(5_000, 5_000), (10_000, 5_000), (20_000, 10_000)];
    let mut tb = Table::new(
        "Fig 6b: peak throughput (items/s) vs window size @60%",
        &["system", "w=5s", "w=10s", "w=20s"],
    );
    let mut tc = Table::new(
        "Fig 6c: accuracy loss vs window size @60%",
        &["system", "w=5s", "w=10s", "w=20s"],
    );
    for sys in System::SAMPLED {
        let mut rb = vec![sys.label().to_string()];
        let mut rc = vec![sys.label().to_string()];
        for &(w, s) in &sizes {
            let wc = WindowConfig::new(w, s);
            let m = run_system(ctx, sys, &items, wc, Query::Sum, 0.6, 500, true);
            rb.push(fmt_throughput(m.summary.throughput));
            rc.push(fmt_pct(m.summary.accuracy_loss));
        }
        tb.row(rb);
        tc.row(rc);
    }
    (tb, tc)
}

/// Fig. 7a — scalability: throughput vs workers (scale-up) and vs nodes
/// (scale-out), sampling fraction 40%.
pub fn fig7a(ctx: &Ctx) -> Table {
    let items = micro_trace(ctx, 1000.0, 55);
    let mut t = Table::new(
        "Fig 7a: peak throughput (items/s) vs parallelism @40%",
        &["system", "w=1", "w=2", "w=4", "w=8 (~1 node)", "w=16 (~2 nodes)", "w=24 (~3 nodes)"],
    );
    for sys in [System::SparkApprox, System::FlinkApprox, System::SparkSrs, System::SparkSts] {
        let mut row = vec![sys.label().to_string()];
        for &w in &[1usize, 2, 4, 8, 16, 24] {
            let m = super::run_system_workers(
                ctx,
                sys,
                &items,
                window_default(),
                Query::Sum,
                0.4,
                500,
                false,
                w,
            );
            row.push(fmt_throughput(m.summary.throughput));
        }
        t.row(row);
    }
    t
}

/// Fig. 7b — throughput at the same (1%) accuracy loss, Gaussian skew.
pub fn fig7b(ctx: &Ctx) -> Table {
    let items = StreamGenerator::new(&StreamConfig::gaussian_skew(10_000.0, 56))
        .take_until(ctx.scale.duration_ms);
    let mut t = Table::new(
        "Fig 7b: peak throughput at 1% accuracy loss — Gaussian skew (80/19/1)",
        &["system", "fraction@1%", "throughput"],
    );
    for sys in System::SAMPLED {
        let f = fraction_for_accuracy(
            |frac| {
                run_system(ctx, sys, &items, window_default(), Query::Sum, frac, 500, true)
                    .summary
                    .accuracy_loss
            },
            0.01,
            6,
        );
        let m = run_system(ctx, sys, &items, window_default(), Query::Sum, f, 500, false);
        t.row(vec![
            sys.label().to_string(),
            fmt_pct(f),
            fmt_throughput(m.summary.throughput),
        ]);
    }
    t
}

/// Fig. 7c — accuracy loss vs fraction, Poisson skew (80/19.99/0.01).
pub fn fig7c(ctx: &Ctx) -> Table {
    let items = StreamGenerator::new(&StreamConfig::poisson_skew(10_000.0, 57))
        .take_until(ctx.scale.duration_ms);
    let mut t = Table::new(
        "Fig 7c: accuracy loss vs sampling fraction — Poisson skew (80/19.99/0.01)",
        &["system", "10%", "20%", "40%", "60%", "80%"],
    );
    for sys in System::SAMPLED {
        let mut row = vec![sys.label().to_string()];
        for &f in &FRACTIONS {
            let m = run_system(ctx, sys, &items, window_default(), Query::Sum, f, 500, true);
            row.push(fmt_pct(m.summary.accuracy_loss));
        }
        t.row(row);
    }
    t
}

/// Fig. 8 — per-window MEAN timeline under Gaussian skew (w=10s, δ=5s):
/// exact vs each Spark-based sampled system, fraction 60%.
pub fn fig8(ctx: &Ctx) -> Table {
    let items = StreamGenerator::new(&StreamConfig::gaussian_skew(10_000.0, 58))
        .take_until(ctx.scale.duration_ms);
    let mut t = Table::new(
        "Fig 8: per-window MEAN every 5s (Gaussian skew, w=10s δ=5s, 60%)",
        &["window-end(s)", "exact", "streamapprox", "spark-srs", "spark-sts"],
    );
    let mut series: Vec<Vec<(u64, f64, f64)>> = Vec::new(); // (end, approx, exact)
    for sys in System::SPARK_SAMPLED {
        let m = crate::pipeline::PipelineBuilder::new()
            .engine(sys.engine())
            .sampler(sys.sampler())
            .budget(crate::budget::QueryBudget::SamplingFraction(0.6))
            .query(Query::Mean)
            .window(window_default())
            .batch_interval_ms(500)
            .workers(ctx.scale.workers)
            .track_exact(true)
            .seed(99)
            .build_with_handle(ctx.handle());
        let r = m.run_items(&items).expect("run");
        series.push(
            r.windows
                .iter()
                .map(|w| (w.end_ms, w.result.value(), w.exact_scalar.unwrap_or(f64::NAN)))
                .collect(),
        );
    }
    let n = series.iter().map(|s| s.len()).min().unwrap_or(0);
    for i in 0..n {
        let (end, _, exact) = series[0][i];
        t.row(vec![
            format!("{}", end / 1000),
            format!("{exact:.2}"),
            format!("{:.2}", series[0][i].1),
            format!("{:.2}", series[1][i].1),
            format!("{:.2}", series[2][i].1),
        ]);
    }
    t
}

/// Shared driver for the two case studies (Figs. 9 and 10).
fn case_study(
    ctx: &Ctx,
    name: &str,
    items: &[Item],
    query: Query,
) -> (Table, Table, Table) {
    let w = window_default();
    let mut ta = Table::new(
        format!("{name} (a): peak throughput (items/s) vs sampling fraction"),
        &["system", "10%", "20%", "40%", "60%", "80%", "native"],
    );
    for sys in [System::SparkApprox, System::FlinkApprox, System::SparkSrs, System::SparkSts] {
        let mut row = vec![sys.label().to_string()];
        for &f in &FRACTIONS {
            let m = run_system(ctx, sys, items, w, query.clone(), f, 500, false);
            row.push(fmt_throughput(m.summary.throughput));
        }
        row.push("-".into());
        ta.row(row);
    }
    for sys in [System::NativeSpark, System::NativeFlink] {
        let m = run_system(ctx, sys, items, w, query.clone(), 1.0, 500, false);
        let mut row = vec![sys.label().to_string()];
        row.extend(std::iter::repeat("-".to_string()).take(5));
        row.push(fmt_throughput(m.summary.throughput));
        ta.row(row);
    }

    let mut tb = Table::new(
        format!("{name} (b): accuracy loss vs sampling fraction"),
        &["system", "10%", "20%", "40%", "60%", "80%"],
    );
    for sys in System::SAMPLED {
        let mut row = vec![sys.label().to_string()];
        for &f in &FRACTIONS {
            let m = run_system(ctx, sys, items, w, query.clone(), f, 500, true);
            row.push(fmt_pct(m.summary.accuracy_loss));
        }
        tb.row(row);
    }

    let mut tc = Table::new(
        format!("{name} (c): peak throughput at 1% accuracy loss"),
        &["system", "fraction@1%", "throughput"],
    );
    for sys in System::SAMPLED {
        let f = fraction_for_accuracy(
            |frac| {
                run_system(ctx, sys, items, w, query.clone(), frac, 500, true)
                    .summary
                    .accuracy_loss
            },
            0.01,
            6,
        );
        let m = run_system(ctx, sys, items, w, query.clone(), f, 500, false);
        tc.row(vec![
            sys.label().to_string(),
            fmt_pct(f),
            fmt_throughput(m.summary.throughput),
        ]);
    }
    (ta, tb, tc)
}

/// Fig. 9 — network traffic analytics (CAIDA-like): per-protocol totals.
pub fn fig9(ctx: &Ctx) -> (Table, Table, Table) {
    let items = CaidaConfig::default().generate(ctx.scale.duration_ms);
    case_study(ctx, "Fig 9: network traffic", &items, Query::PerStratumSum)
}

/// Fig. 10 — NYC taxi analytics: per-borough mean trip distance.
pub fn fig10(ctx: &Ctx) -> (Table, Table, Table) {
    let items = TaxiConfig::default().generate(ctx.scale.duration_ms);
    case_study(ctx, "Fig 10: NYC taxi", &items, Query::PerStratumMean)
}

/// Sketch workloads — the three new query classes (quantile, distinct,
/// top-k) over the CAIDA-style sources trace, swept across sampling
/// fractions.  Reported per fraction: approximate value, native error
/// bound, and (for top-k) whether the true top-3 sources were recovered —
/// the acceptance gate of `examples/heavy_hitters.rs`.
pub fn sketch_workloads(ctx: &Ctx) -> Table {
    use crate::datasets::CaidaSourcesConfig;

    let cfg = CaidaSourcesConfig::default();
    let items = cfg.generate(ctx.scale.duration_ms);

    let mut t = Table::new(
        "Sketch workloads: quantile / distinct / top-k vs sampling fraction — CAIDA sources",
        &["query", "10%", "40%", "80%"],
    );
    for (label, query) in [
        ("p95 flow bytes", Query::Quantile(0.95)),
        ("distinct flow sizes", Query::Distinct),
        ("top-3 sources (mass)", Query::TopK(3)),
    ] {
        let mut row = vec![label.to_string()];
        for &f in &[0.1, 0.4, 0.8] {
            let m = crate::pipeline::PipelineBuilder::new()
                .engine(crate::engine::EngineKind::Pipelined)
                .sampler(crate::sampling::SamplerKind::Oasrs)
                .budget(crate::budget::QueryBudget::SamplingFraction(f))
                .query(query.clone())
                .window(window_default())
                .workers(ctx.scale.workers)
                .track_exact(true)
                .seed(101)
                .build_with_handle(ctx.handle());
            let r = m.run_items(&items).expect("run");
            let last = r.windows.last().expect("windows");
            let cell = match &query {
                Query::TopK(_) => {
                    let top = last.result.top_k.as_ref().expect("top-k");
                    // grade against the *same window's* exact counts — the
                    // window-local top-3 can differ from the whole-trace one
                    let exact = last.exact_per_stratum.as_ref().expect("exact counts");
                    let recovered = crate::query::top_k_strata(exact, 3)
                        .iter()
                        .all(|&s| top.iter().any(|&(k, _)| k as usize == s));
                    format!(
                        "{:.0} ({})",
                        last.result.value(),
                        if recovered { "top-3 ok" } else { "MISS" }
                    )
                }
                _ => format!("{:.0} ±{:.0}", last.result.value(), last.result.scalar.map(|c| c.bound).unwrap_or(0.0)),
            };
            row.push(cell);
        }
        t.row(row);
    }
    t
}

/// Window scaling — the long-window/small-slide family the pane-store
/// assembler opens (not a paper figure; the paper stops at w=20s, δ=10s).
/// Fixed 500 ms slide, window/slide ratios {4, 16, 64}: per-slide assembler
/// cost is O(panes evicted + 1), so throughput and window latency should
/// stay flat as the ratio grows (the seed's merge-all path degraded
/// linearly).  Table (a): linear SUM query on both engines.  Table (b):
/// sliding p95 quantile through the pane-level sketch store — sliding
/// sketch windows at ratios the per-window rebuild could not sustain.
pub fn window_scaling(ctx: &Ctx) -> (Table, Table) {
    const SLIDE_MS: u64 = 500;
    const RATIOS: [u64; 3] = [4, 16, 64];

    let items = micro_trace(ctx, 1000.0, 59);
    let mut ta = Table::new(
        "Window scaling (a): throughput | mean window latency — SUM, slide 500ms, ratio w/δ",
        &["system", "ratio 4 (w=2s)", "ratio 16 (w=8s)", "ratio 64 (w=32s)"],
    );
    for sys in [System::SparkApprox, System::FlinkApprox] {
        let mut row = vec![sys.label().to_string()];
        for &ratio in &RATIOS {
            let wc = WindowConfig::new(SLIDE_MS * ratio, SLIDE_MS);
            let m = run_system(ctx, sys, &items, wc, Query::Sum, 0.6, SLIDE_MS, true);
            row.push(format!(
                "{} | {:.0}us",
                fmt_throughput(m.summary.throughput),
                m.summary.window_latency_ns / 1e3,
            ));
        }
        ta.row(row);
    }

    let mut tb = Table::new(
        "Window scaling (b): sliding p95 (pane sketches) — throughput | window latency",
        &["system", "ratio 4 (w=2s)", "ratio 16 (w=8s)", "ratio 64 (w=32s)"],
    );
    for sys in [System::SparkApprox, System::FlinkApprox] {
        let mut row = vec![sys.label().to_string()];
        for &ratio in &RATIOS {
            let wc = WindowConfig::new(SLIDE_MS * ratio, SLIDE_MS);
            let m = run_system(
                ctx,
                sys,
                &items,
                wc,
                Query::Quantile(0.95),
                0.6,
                SLIDE_MS,
                false,
            );
            row.push(format!(
                "{} | {:.0}us",
                fmt_throughput(m.summary.throughput),
                m.summary.window_latency_ns / 1e3,
            ));
        }
        tb.row(row);
    }
    (ta, tb)
}

/// Fig. 11 — total processing latency of both case-study datasets @60%.
pub fn fig11(ctx: &Ctx) -> Table {
    let caida = CaidaConfig::default().generate(ctx.scale.duration_ms);
    let taxi = TaxiConfig::default().generate(ctx.scale.duration_ms);
    let mut t = Table::new(
        "Fig 11: total processing time (ms) @60%",
        &["system", "network-traffic", "nyc-taxi"],
    );
    for sys in System::SPARK_SAMPLED {
        let mc = run_system(
            ctx, sys, &caida, window_default(), Query::PerStratumSum, 0.6, 500, false,
        );
        let mt = run_system(
            ctx, sys, &taxi, window_default(), Query::PerStratumMean, 0.6, 500, false,
        );
        t.row(vec![
            sys.label().to_string(),
            format!("{:.1}", mc.summary.wall_ns / 1e6),
            format!("{:.1}", mt.summary.wall_ns / 1e6),
        ]);
    }
    t
}
