"""L2 window-aggregation graph: Eq. 1-9 vs a straight numpy implementation,
statistical sanity of the estimators, and chunk-combine equivalence
(the path the rust runtime uses for windows larger than the biggest
AOT variant).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import window_aggregate, window_aggregate_ref


def numpy_oracle(ids, values, c, n_cap):
    """Independent numpy implementation of Eq. 1-9."""
    k = len(c)
    y = np.zeros(k)
    s1 = np.zeros(k)
    s2 = np.zeros(k)
    for i, v in zip(ids, values):
        if i >= 0:
            y[i] += 1
            s1[i] += v
            s2[i] += v * v
    weights = np.where(c > n_cap, c / np.maximum(n_cap, 1), 1.0)
    strata_sums = s1 * weights
    total = strata_sums.sum()
    mean = total / max(c.sum(), 1.0)
    s_sq = np.zeros(k)
    for i in range(k):
        if y[i] > 1:
            ybar = s1[i] / y[i]
            s_sq[i] = max((s2[i] - y[i] * ybar * ybar) / (y[i] - 1), 0.0)
    fpc = np.maximum(c - y, 0.0)
    var_sum = sum(
        c[i] * fpc[i] * s_sq[i] / y[i] for i in range(k) if y[i] > 0
    )
    omega = c / max(c.sum(), 1.0)
    var_mean = sum(
        omega[i] ** 2 * (s_sq[i] / y[i]) * fpc[i] / c[i]
        for i in range(k)
        if y[i] > 0 and c[i] > 0
    )
    return weights, strata_sums, total, mean, var_sum, var_mean


def make_case(seed, n=1024, k=16, cap=40):
    rng = np.random.default_rng(seed)
    ids = rng.integers(-1, k, size=n).astype(np.int32)
    values = rng.normal(100.0, 10.0, size=n).astype(np.float32)
    # arrival counters >= selected counts
    y = np.array([(ids == i).sum() for i in range(k)], dtype=np.float32)
    extra = rng.integers(0, 200, size=k).astype(np.float32)
    c = y + extra
    n_cap = np.full(k, cap, dtype=np.float32)
    # clip Y to capacity semantics: in real OASRS Y_i <= N_i; here we just
    # set capacity high enough or let weights handle it — both valid inputs.
    return ids, values, c, n_cap


class TestModelVsNumpy:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_scalars_match(self, seed):
        ids, values, c, n_cap = make_case(seed)
        partials, weights, strata_sums, scalars = window_aggregate(
            jnp.asarray(ids), jnp.asarray(values), jnp.asarray(c), jnp.asarray(n_cap),
            num_strata=16,
        )
        w_np, ss_np, total, mean, var_sum, var_mean = numpy_oracle(
            ids, values, c, n_cap
        )
        np.testing.assert_allclose(np.asarray(weights), w_np, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(strata_sums), ss_np, rtol=1e-4)
        np.testing.assert_allclose(float(scalars[0]), total, rtol=1e-4)
        np.testing.assert_allclose(float(scalars[1]), mean, rtol=1e-4)
        np.testing.assert_allclose(float(scalars[2]), var_sum, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(float(scalars[3]), var_mean, rtol=1e-3, atol=1e-6)

    def test_pallas_and_ref_graphs_agree(self):
        ids, values, c, n_cap = make_case(7)
        a = window_aggregate(
            jnp.asarray(ids), jnp.asarray(values), jnp.asarray(c), jnp.asarray(n_cap),
            num_strata=16,
        )
        b = window_aggregate_ref(
            jnp.asarray(ids), jnp.asarray(values), jnp.asarray(c), jnp.asarray(n_cap),
            num_strata=16,
        )
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5)

    def test_weight_law(self):
        """Eq. 1: W_i = C_i/N_i when C_i > N_i else exactly 1."""
        k = 16
        ids = np.repeat(np.arange(k), 16).astype(np.int32)
        values = np.ones(k * 16, dtype=np.float32)
        pad = np.full(1024 - k * 16, -1, dtype=np.int32)
        ids = np.concatenate([ids, pad])
        values = np.concatenate([values, np.zeros(len(pad), dtype=np.float32)])
        c = np.arange(1, k + 1, dtype=np.float32) * 10  # 10..160
        n_cap = np.full(k, 50.0, dtype=np.float32)
        _, weights, _, _ = window_aggregate(
            jnp.asarray(ids), jnp.asarray(values), jnp.asarray(c), jnp.asarray(n_cap),
            num_strata=k,
        )
        w = np.asarray(weights)
        for i in range(k):
            if c[i] > 50.0:
                assert w[i] == pytest.approx(c[i] / 50.0)
            else:
                assert w[i] == 1.0


class TestEstimatorQuality:
    def test_estimate_tracks_true_sum(self):
        """Stratified estimate of the sum should be close to the true sum
        and the error should be within ~4 sigma of the variance estimate."""
        rng = np.random.default_rng(42)
        k = 3
        sizes = [4000, 1000, 100]
        mus = [10.0, 1000.0, 10000.0]
        sigmas = [5.0, 50.0, 500.0]
        cap = 200
        all_ids, all_vals = [], []
        true_sum = 0.0
        c = np.zeros(16, dtype=np.float32)
        for i, (sz, mu, sg) in enumerate(zip(sizes, mus, sigmas)):
            data = rng.normal(mu, sg, size=sz)
            true_sum += data.sum()
            c[i] = sz
            take = min(cap, sz)
            sel = rng.choice(data, size=take, replace=False)
            all_ids += [i] * take
            all_vals += list(sel)
        n = 1024
        ids = np.full(n, -1, dtype=np.int32)
        vals = np.zeros(n, dtype=np.float32)
        ids[: len(all_ids)] = all_ids
        vals[: len(all_vals)] = all_vals
        n_cap = np.full(16, cap, dtype=np.float32)
        _, _, _, scalars = window_aggregate(
            jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(c), jnp.asarray(n_cap),
            num_strata=16,
        )
        est, var = float(scalars[0]), float(scalars[2])
        sigma = np.sqrt(var)
        assert abs(est - true_sum) < 4 * sigma + 1e-6
        # relative error small: dominant stratum fully structured
        assert abs(est - true_sum) / abs(true_sum) < 0.05

    def test_fully_sampled_zero_variance(self):
        """If every stratum is fully sampled (C_i = Y_i), Var == 0 and the
        estimate is exact."""
        rng = np.random.default_rng(3)
        k = 4
        per = 100
        ids = np.repeat(np.arange(k), per).astype(np.int32)
        vals = rng.normal(50.0, 5.0, size=k * per).astype(np.float32)
        pad_n = 1024 - k * per
        ids = np.concatenate([ids, np.full(pad_n, -1, np.int32)])
        vals = np.concatenate([vals, np.zeros(pad_n, np.float32)])
        c = np.zeros(16, np.float32)
        c[:k] = per
        n_cap = np.full(16, 200.0, np.float32)
        _, _, _, scalars = window_aggregate(
            jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(c), jnp.asarray(n_cap),
            num_strata=16,
        )
        assert float(scalars[2]) == pytest.approx(0.0, abs=1e-3)
        assert float(scalars[0]) == pytest.approx(float(vals.sum()), rel=1e-5)


class TestChunkCombine:
    """Large windows are split into chunks; per-stratum partials combine by
    addition and the estimate is finished from the combined partials.  This
    must equal running the whole window through one big variant."""

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_chunked_equals_whole(self, seed):
        rng = np.random.default_rng(seed)
        k = 16
        n = 2048
        ids = rng.integers(-1, k, size=n).astype(np.int32)
        vals = rng.normal(10.0, 3.0, size=n).astype(np.float32)
        c = np.array([(ids == i).sum() for i in range(k)], np.float32) * 2
        n_cap = np.full(k, 64.0, np.float32)

        whole, _, _, whole_scalars = window_aggregate(
            jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(c), jnp.asarray(n_cap),
            num_strata=k,
        )

        # chunked: run halves, combine partials, re-estimate via the graph
        # trick — feed combined partials through a zero-item call is not
        # possible, so replicate the estimate in numpy (the rust runtime
        # does the same arithmetic).
        half = n // 2
        p1, _, _, _ = window_aggregate(
            jnp.asarray(ids[:half]), jnp.asarray(vals[:half]),
            jnp.asarray(c), jnp.asarray(n_cap), num_strata=k,
        )
        p2, _, _, _ = window_aggregate(
            jnp.asarray(ids[half:]), jnp.asarray(vals[half:]),
            jnp.asarray(c), jnp.asarray(n_cap), num_strata=k,
        )
        combined = np.asarray(p1) + np.asarray(p2)
        np.testing.assert_allclose(combined, np.asarray(whole), rtol=1e-5)

        # finish the estimate from combined partials (rust-side arithmetic)
        y, s1 = combined[:, 0], combined[:, 1]
        weights = np.where(c > n_cap, c / np.maximum(n_cap, 1), 1.0)
        est = (s1 * weights).sum()
        np.testing.assert_allclose(est, float(whole_scalars[0]), rtol=1e-4)
