"""AOT lowering smoke tests: HLO text is produced, parsable-looking, and the
manifest layout matches what the rust runtime expects."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile.aot import lower_variant, NUM_STRATA


class TestLowering:
    def test_lower_small_variant(self):
        text = lower_variant(256, NUM_STRATA)
        assert "ENTRY" in text
        assert "HloModule" in text
        # 4 outputs tupled
        assert "tuple" in text.lower()

    def test_lower_is_deterministic(self):
        a = lower_variant(256, NUM_STRATA)
        b = lower_variant(256, NUM_STRATA)
        assert a == b

    def test_shapes_in_text(self):
        text = lower_variant(1024, NUM_STRATA)
        # input parameter shapes appear in the HLO signature
        assert "s32[1024]" in text
        assert "f32[1024]" in text
        assert f"f32[{NUM_STRATA}]" in text


class TestCli:
    def test_cli_writes_artifacts_and_manifest(self):
        with tempfile.TemporaryDirectory() as d:
            env = dict(os.environ)
            subprocess.run(
                [
                    sys.executable,
                    "-m",
                    "compile.aot",
                    "--out-dir",
                    d,
                    "--capacities",
                    "256",
                ],
                check=True,
                cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                env=env,
            )
            files = set(os.listdir(d))
            assert "window_agg_n256.hlo.txt" in files
            assert "manifest.json" in files
            with open(os.path.join(d, "manifest.json")) as f:
                m = json.load(f)
            assert m["num_strata"] == NUM_STRATA
            assert m["pad_id"] == -1
            assert [o["name"] for o in m["outputs"]] == [
                "partials",
                "weights",
                "strata_sums",
                "scalars",
            ]
            assert m["variants"][0]["n_items"] == 256
