"""L1 Pallas kernel vs pure-jnp reference — the core correctness signal.

Hypothesis sweeps shapes/strata/value ranges; fixed cases pin edge
behaviours (padding, empty strata, block boundaries, negative values).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import stratified_aggregate_ref
from compile.kernels.stratified_agg import stratified_aggregate


def run_both(ids, values, num_strata, block_items=None):
    ids = jnp.asarray(ids, dtype=jnp.int32)
    values = jnp.asarray(values, dtype=jnp.float32)
    kwargs = {}
    if block_items is not None:
        kwargs["block_items"] = block_items
    got = stratified_aggregate(ids, values, num_strata=num_strata, **kwargs)
    want = stratified_aggregate_ref(ids, values, num_strata=num_strata)
    return np.asarray(got), np.asarray(want)


class TestFixedCases:
    def test_single_stratum(self):
        got, want = run_both([0] * 256, np.arange(256.0), num_strata=4)
        np.testing.assert_allclose(got, want, rtol=1e-6)
        assert got[0, 0] == 256.0
        assert got[1:, 0].sum() == 0.0

    def test_all_padding(self):
        got, want = run_both([-1] * 256, np.ones(256), num_strata=8)
        np.testing.assert_allclose(got, want)
        assert got.sum() == 0.0

    def test_mixed_padding(self):
        ids = np.array([0, -1, 1, -1] * 64)
        vals = np.array([2.0, 99.0, 3.0, 99.0] * 64)
        got, want = run_both(ids, vals, num_strata=2)
        np.testing.assert_allclose(got, want, rtol=1e-6)
        # padding values must not leak into any stratum
        assert got[0, 1] == pytest.approx(2.0 * 64)
        assert got[1, 1] == pytest.approx(3.0 * 64)

    def test_round_robin_strata(self):
        k = 16
        n = 1024
        ids = np.arange(n) % k
        vals = np.ones(n)
        got, want = run_both(ids, vals, num_strata=k)
        np.testing.assert_allclose(got, want, rtol=1e-6)
        np.testing.assert_allclose(got[:, 0], n / k)

    def test_negative_values(self):
        ids = np.zeros(256, dtype=np.int32)
        vals = np.linspace(-100, 100, 256)
        got, want = run_both(ids, vals, num_strata=1)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
        # sum of symmetric range ~ 0, sumsq strictly positive
        assert abs(got[0, 1]) < 1e-3
        assert got[0, 2] > 0

    def test_multi_block_accumulation(self):
        """Grid > 1: accumulation across blocks must match reference."""
        n = 2048  # 8 blocks of 256
        rng = np.random.default_rng(0)
        ids = rng.integers(0, 16, size=n)
        vals = rng.normal(1000.0, 50.0, size=n)
        got, want = run_both(ids, vals, num_strata=16)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_custom_block_size(self):
        n = 512
        rng = np.random.default_rng(1)
        ids = rng.integers(-1, 4, size=n)
        vals = rng.normal(size=n)
        for b in (64, 128, 512):
            got, want = run_both(ids, vals, num_strata=4, block_items=b)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_block_size_must_divide(self):
        with pytest.raises(ValueError):
            stratified_aggregate(
                jnp.zeros(100, jnp.int32),
                jnp.zeros(100, jnp.float32),
                num_strata=4,
                block_items=64,
            )

    def test_length_mismatch(self):
        with pytest.raises(ValueError):
            stratified_aggregate(
                jnp.zeros(256, jnp.int32),
                jnp.zeros(128, jnp.float32),
                num_strata=4,
            )

    def test_out_of_range_ids_dropped(self):
        """ids >= num_strata match no one-hot column, like padding."""
        ids = np.array([0, 5, 1, 9] * 64)  # 5 and 9 out of range for K=2
        vals = np.ones(256)
        got, want = run_both(ids, vals, num_strata=2)
        # ref routes invalid ids >= K into the scratch segment only if they
        # equal K; segment_sum with larger ids would error, so clamp in the
        # comparison: kernel must count exactly the in-range items.
        assert got[0, 0] == 64.0
        assert got[1, 0] == 64.0

    def test_dtype_output(self):
        got = stratified_aggregate(
            jnp.zeros(256, jnp.int32), jnp.ones(256, jnp.float32), num_strata=4
        )
        assert got.dtype == jnp.float32
        assert got.shape == (4, 3)


@st.composite
def sample_case(draw):
    num_strata = draw(st.integers(min_value=1, max_value=16))
    blocks = draw(st.integers(min_value=1, max_value=4))
    block_items = draw(st.sampled_from([64, 128, 256]))
    n = blocks * block_items
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    frac_pad = draw(st.floats(min_value=0.0, max_value=0.9))
    scale = draw(st.sampled_from([1.0, 50.0, 1e4]))
    return num_strata, n, block_items, seed, frac_pad, scale


class TestHypothesis:
    @settings(max_examples=25, deadline=None)
    @given(sample_case())
    def test_kernel_matches_ref(self, case):
        num_strata, n, block_items, seed, frac_pad, scale = case
        rng = np.random.default_rng(seed)
        ids = rng.integers(0, num_strata, size=n)
        pad = rng.random(n) < frac_pad
        ids = np.where(pad, -1, ids)
        vals = rng.normal(0.0, scale, size=n)
        got, want = run_both(ids, vals, num_strata, block_items=block_items)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3 * scale)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_counts_are_exact_integers(self, seed):
        rng = np.random.default_rng(seed)
        n, k = 512, 8
        ids = rng.integers(-1, k, size=n)
        vals = rng.normal(size=n)
        got, _ = run_both(ids, vals, k)
        counts = got[:, 0]
        np.testing.assert_array_equal(counts, np.round(counts))
        assert counts.sum() == (ids >= 0).sum()
