"""AOT lowering: L2 window-aggregation graph -> HLO text artifacts.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 rust crate links) rejects with
``proto.id() <= INT_MAX``.  The HLO text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (one HLO module per static item-capacity N):

    artifacts/window_agg_n{N}.hlo.txt   for N in CAPACITIES
    artifacts/manifest.json             shapes + output layout for rust

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import make_jitted

# Item capacities of the AOT variants. The rust runtime picks the smallest
# variant that fits a window sample and chunks anything larger than the max.
CAPACITIES = (1024, 4096, 16384)
NUM_STRATA = 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n_items: int, num_strata: int) -> str:
    fn, specs = make_jitted(n_items, num_strata)
    return to_hlo_text(fn.lower(*specs))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--out", default=None, help="legacy single-file path (ignored)")
    parser.add_argument(
        "--capacities", type=int, nargs="*", default=list(CAPACITIES)
    )
    parser.add_argument("--num-strata", type=int, default=NUM_STRATA)
    args = parser.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    variants = []
    for n in sorted(args.capacities):
        text = lower_variant(n, args.num_strata)
        path = os.path.join(args.out_dir, f"window_agg_n{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        variants.append(
            {
                "n_items": n,
                "num_strata": args.num_strata,
                "file": os.path.basename(path),
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "num_strata": args.num_strata,
        "pad_id": -1,
        # Tupled outputs, in order, with row-major shapes:
        "outputs": [
            {"name": "partials", "shape": [args.num_strata, 3]},
            {"name": "weights", "shape": [args.num_strata]},
            {"name": "strata_sums", "shape": [args.num_strata]},
            {
                "name": "scalars",
                "shape": [6],
                "fields": ["sum", "mean", "var_sum", "var_mean", "total_c", "total_y"],
            },
        ],
        "inputs": ["ids:i32[N]", "values:f32[N]", "c:f32[K]", "n_cap:f32[K]"],
        "variants": variants,
        "jax_version": jax.__version__,
    }
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
