"""L2: StreamApprox per-window approximate-query graph (build-time JAX).

Implements the paper's estimation pipeline over one window sample produced by
the L3 OASRS sampler (or by the SRS/STS baselines — the math is identical
once per-stratum counters are supplied):

  * per-stratum partials (Y_i, sum I_ij, sum I_ij^2) — via the L1 Pallas
    kernel ``kernels.stratified_agg`` so the hot loop lowers into the same
    HLO module,
  * weights W_i = C_i / N_i if C_i > N_i else 1              (Eq. 1),
  * per-stratum estimated sums SUM_i = (sum I_ij) * W_i      (Eq. 2),
  * total SUM = sum_i SUM_i                                  (Eq. 3),
  * MEAN = SUM / sum_i C_i                                   (Eq. 4),
  * s_i^2 sample variance of each stratum's sample           (Eq. 7),
  * Var(SUM)  = sum_i C_i (C_i - Y_i) s_i^2 / Y_i            (Eq. 6),
  * Var(MEAN) = sum_i w_i^2 (s_i^2 / Y_i) (C_i - Y_i)/C_i    (Eq. 9),
    with w_i = C_i / sum C_i.

Shapes are static for AOT: N items (padded with id = -1), K strata.  The
graph returns the raw per-stratum partials *as well as* the fused estimates,
so the Rust runtime can either consume the estimates directly (single-chunk
windows) or combine partials across chunks of a large window and finish the
estimate Rust-side; tests cross-check both paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.stratified_agg import stratified_aggregate
from compile.kernels.ref import stratified_aggregate_ref


def _estimates_from_partials(partials, c, n_cap):
    """Eq. 1-9 given per-stratum partials [K,3], arrivals c[K], capacities n_cap[K]."""
    y = partials[:, 0]  # Y_i: items actually selected
    s1 = partials[:, 1]  # sum of selected items
    s2 = partials[:, 2]  # sum of squares of selected items

    # Eq. 1 — weight per stratum. Strata with C_i <= N_i keep weight 1.
    weights = jnp.where(c > n_cap, c / jnp.maximum(n_cap, 1.0), 1.0)

    # Eq. 2/3 — estimated per-stratum and total sums.
    strata_sums = s1 * weights
    total_sum = jnp.sum(strata_sums)

    # Eq. 4 — estimated mean over all arrived items.
    total_c = jnp.sum(c)
    mean = total_sum / jnp.maximum(total_c, 1.0)

    # Eq. 7 — per-stratum sample variance s_i^2 (0 when Y_i < 2).
    ybar = s1 / jnp.maximum(y, 1.0)
    s_sq = jnp.where(y > 1.0, (s2 - y * ybar * ybar) / jnp.maximum(y - 1.0, 1.0), 0.0)
    # Guard tiny negatives from floating-point cancellation.
    s_sq = jnp.maximum(s_sq, 0.0)

    # Eq. 6 — variance of the SUM estimate.
    fpc = jnp.maximum(c - y, 0.0)  # 0 when the stratum was fully sampled
    var_sum_terms = jnp.where(y > 0.0, c * fpc * s_sq / jnp.maximum(y, 1.0), 0.0)
    var_sum = jnp.sum(var_sum_terms)

    # Eq. 9 — variance of the MEAN estimate.
    omega = c / jnp.maximum(total_c, 1.0)
    var_mean_terms = jnp.where(
        (y > 0.0) & (c > 0.0),
        omega * omega * (s_sq / jnp.maximum(y, 1.0)) * fpc / jnp.maximum(c, 1.0),
        0.0,
    )
    var_mean = jnp.sum(var_mean_terms)

    total_y = jnp.sum(y)
    scalars = jnp.stack([total_sum, mean, var_sum, var_mean, total_c, total_y])
    return weights, strata_sums, scalars


def window_aggregate(ids, values, c, n_cap, *, num_strata: int, interpret=True):
    """Full per-window job: L1 kernel + Eq. 1-9 estimates.

    Args:
      ids: i32[N] stratum id per sampled item (-1 = padding).
      values: f32[N] sampled item values.
      c: f32[K] per-stratum arrival counters C_i for the window.
      n_cap: f32[K] per-stratum reservoir capacities N_i.

    Returns:
      (partials f32[K,3], weights f32[K], strata_sums f32[K], scalars f32[6])
      with scalars = [SUM, MEAN, Var(SUM), Var(MEAN), total_C, total_Y].
    """
    partials = stratified_aggregate(
        ids, values, num_strata=num_strata, interpret=interpret
    )
    weights, strata_sums, scalars = _estimates_from_partials(partials, c, n_cap)
    return partials, weights, strata_sums, scalars


def window_aggregate_ref(ids, values, c, n_cap, *, num_strata: int):
    """Same estimation graph over the pure-jnp reference kernel (test oracle)."""
    partials = stratified_aggregate_ref(ids, values, num_strata=num_strata)
    weights, strata_sums, scalars = _estimates_from_partials(partials, c, n_cap)
    return partials, weights, strata_sums, scalars


def make_jitted(n_items: int, num_strata: int):
    """jit-able closure with static shapes, for AOT lowering and tests."""

    def fn(ids, values, c, n_cap):
        return window_aggregate(ids, values, c, n_cap, num_strata=num_strata)

    specs = (
        jax.ShapeDtypeStruct((n_items,), jnp.int32),
        jax.ShapeDtypeStruct((n_items,), jnp.float32),
        jax.ShapeDtypeStruct((num_strata,), jnp.float32),
        jax.ShapeDtypeStruct((num_strata,), jnp.float32),
    )
    return jax.jit(fn), specs
