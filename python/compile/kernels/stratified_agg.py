"""L1 Pallas kernel: per-stratum (segmented) aggregation of a window sample.

The analytics hot spot of StreamApprox is computing, for every stratum i in a
window sample, the selected-item count Y_i, the sum of selected items, and the
sum of squares (needed for the variance estimate, Eq. 7 of the paper).

TPU adaptation (DESIGN.md SS5): a GPU implementation would scatter-add with
atomics keyed by stratum id.  On TPU we recast the scatter-add as a one-hot
matmul so it lands on the MXU: for a block of B items we materialize
``onehot[B, K] = (ids[:, None] == iota(K)[None, :])`` in VMEM and compute

    partial[K, 3] += onehot.T @ [ones, values, values**2]

accumulating the f32[K, 3] partials across the item-axis grid.  The K axis is
small (16 strata) and stays VMEM-resident for the whole kernel; only the item
blocks stream through.  Padding items carry id = -1 and match no one-hot
column, so they drop out without a separate mask pass.

The kernel is lowered with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls; numerics are validated through the interpret path against
``ref.py`` (pure jnp) by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block of items streamed through VMEM per grid step.  256 items x
# K=16 one-hot = 16 KB f32 in VMEM — far below the ~16 MB budget; chosen so
# the [B, 3] feature tile and the one-hot both fit comfortably while keeping
# the grid short.
DEFAULT_BLOCK_ITEMS = 256


def _agg_kernel(ids_ref, values_ref, out_ref, *, num_strata: int):
    """One grid step: aggregate a block of items into the [K, 3] accumulator.

    out_ref accumulates across the grid (same block for every step), so we
    initialise it on the first step and add partials afterwards.
    """
    step = pl.program_id(0)

    ids = ids_ref[...]  # i32[B]
    values = values_ref[...].astype(jnp.float32)  # f32[B]

    # One-hot over strata: padding ids (-1) match nothing.
    strata = jax.lax.iota(jnp.int32, num_strata)  # i32[K]
    onehot = (ids[:, None] == strata[None, :]).astype(jnp.float32)  # [B, K]

    # Feature matrix: count, sum, sum of squares — fused into one matmul so
    # the MXU sees a single [K, B] x [B, 3] contraction per block.
    feats = jnp.stack(
        [jnp.ones_like(values), values, values * values], axis=1
    )  # [B, 3]

    partial = jnp.dot(
        onehot.T, feats, preferred_element_type=jnp.float32
    )  # [K, 3]

    @pl.when(step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(step != 0)
    def _acc():
        out_ref[...] += partial


def stratified_aggregate(
    ids: jax.Array,
    values: jax.Array,
    *,
    num_strata: int,
    block_items: int = DEFAULT_BLOCK_ITEMS,
    interpret: bool = True,
) -> jax.Array:
    """Per-stratum [count, sum, sum_sq] of ``values`` grouped by ``ids``.

    Args:
      ids: i32[N] stratum id per item; -1 marks padding (ignored).
      values: f32[N] item values.
      num_strata: K, the number of strata (output rows).
      block_items: items per grid step (must divide N).
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      f32[K, 3]: column 0 = Y_i (selected count), column 1 = sum of selected
      items, column 2 = sum of squares of selected items.
    """
    n = ids.shape[0]
    if values.shape[0] != n:
        raise ValueError(f"ids/values length mismatch: {n} vs {values.shape[0]}")
    if n % block_items != 0:
        raise ValueError(f"N={n} must be a multiple of block_items={block_items}")

    grid = (n // block_items,)
    kernel = functools.partial(_agg_kernel, num_strata=num_strata)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_items,), lambda i: (i,)),
            pl.BlockSpec((block_items,), lambda i: (i,)),
        ],
        # The accumulator is the same [K, 3] block on every grid step.
        out_specs=pl.BlockSpec((num_strata, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_strata, 3), jnp.float32),
        interpret=interpret,
    )(ids, values)
