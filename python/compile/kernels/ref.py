"""Pure-jnp oracle for the L1 stratified aggregation kernel.

Used by pytest/hypothesis to validate ``stratified_agg.stratified_aggregate``
and by the L2 model tests.  Deliberately written with jnp segment ops — no
Pallas, no blocking — so it is an independent implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stratified_aggregate_ref(
    ids: jax.Array, values: jax.Array, *, num_strata: int
) -> jax.Array:
    """Reference per-stratum [count, sum, sum_sq]; ids of -1 are padding."""
    values = values.astype(jnp.float32)
    valid = ids >= 0
    # Route padding to a scratch segment K and slice it off afterwards.
    seg = jnp.where(valid, ids, num_strata)
    count = jax.ops.segment_sum(
        valid.astype(jnp.float32), seg, num_segments=num_strata + 1
    )
    total = jax.ops.segment_sum(
        jnp.where(valid, values, 0.0), seg, num_segments=num_strata + 1
    )
    sumsq = jax.ops.segment_sum(
        jnp.where(valid, values * values, 0.0), seg, num_segments=num_strata + 1
    )
    return jnp.stack([count, total, sumsq], axis=1)[:num_strata]
