//! NYC taxi ride analytics case study (paper §6.3): average trip distance
//! per borough per sliding window, on a synthetic DEBS'15-like ride stream.
//!
//! ```bash
//! make artifacts && cargo run --release --example taxi_rides
//! ```

use streamapprox::datasets::taxi::{TaxiConfig, BOROUGHS};
use streamapprox::prelude::*;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let svc = match ComputeService::start(Backend::Xla, None) {
        Ok(s) => {
            println!("compute backend: XLA (AOT artifacts)");
            s
        }
        Err(e) => {
            println!("compute backend: native ({e})");
            ComputeService::native()
        }
    };

    let trace = TaxiConfig::default().generate(60_000);
    println!("replaying {} rides", trace.len());

    // Accuracy-budget run: keep the mean's error bound under 0.5%,
    // letting the adaptive feedback pick the fraction.
    let pipeline = PipelineBuilder::new()
        .engine(EngineKind::Pipelined)
        .sampler(SamplerKind::Oasrs)
        .budget(QueryBudget::TargetRelativeError { target: 0.005, initial_fraction: 0.2 })
        .query(Query::PerStratumMean)
        .window(WindowConfig::paper_default())
        .workers(2)
        .build_with_handle(svc.handle());
    let r = pipeline.run_items(&trace)?;

    println!(
        "throughput {:.0} items/s, mean loss {:.3}%, {} windows",
        r.throughput(),
        r.mean_accuracy_loss() * 100.0,
        r.windows.len()
    );

    if let Some(w) = r.windows.last() {
        let approx = w.result.per_stratum.as_ref().unwrap();
        let exact = w.exact_per_stratum.as_ref().unwrap();
        println!(
            "\nlast window ({}-{} s): avg trip distance (miles)",
            w.start_ms / 1000,
            w.end_ms / 1000
        );
        println!("{:<15} {:>8} {:>8} {:>8}", "borough", "approx", "exact", "loss");
        for (b, name) in BOROUGHS.iter().enumerate() {
            if exact[b] > 0.0 {
                println!(
                    "{:<15} {:>8.2} {:>8.2} {:>7.2}%",
                    name,
                    approx[b],
                    exact[b],
                    (approx[b] - exact[b]).abs() / exact[b] * 100.0
                );
            }
        }
    }
    Ok(())
}
