//! Heavy hitters over a CAIDA-style source trace (paper §6.2 workload,
//! extended to the sketch subsystem): per-window top-k sources under
//! varying sampling fractions.
//!
//! ```bash
//! cargo run --release --example heavy_hitters
//! ```
//!
//! Part 1 runs `Query::TopK(10)` end-to-end (OASRS sampling → per-shard
//! sketches → barrier-free merge → Count-Min-bounded counts) at fractions
//! {0.8, 0.4, 0.1} and checks the true top-3 sources are recovered in every
//! window at every fraction.  Part 2 uses the `HeavyHitters` sketch
//! directly over 10 000 synthetic source IPs — the regime where the
//! candidate set, not the stratum table, does the work.

use streamapprox::budget::QueryBudget;
use streamapprox::datasets::CaidaSourcesConfig;
use streamapprox::engine::EngineKind;
use streamapprox::prelude::*;
use streamapprox::util::rng::Rng;
use streamapprox::util::table::Table;

fn main() {
    // ---- Part 1: Query::TopK through the full pipeline --------------------
    let cfg = CaidaSourcesConfig::default();
    let items = cfg.generate(20_000);
    println!(
        "trace: {} flows over 20 s, {} sources, zipf({}) popularity\n",
        items.len(),
        cfg.sources,
        cfg.exponent
    );

    let mut table = Table::new(
        "top-10 sources by estimated flow count (last window, w = 10 s)",
        &["rank", "80% sample", "40% sample", "10% sample", "exact"],
    );

    let mut per_fraction: Vec<Vec<(u64, f64)>> = Vec::new();
    let mut exact_counts = vec![0.0f64; streamapprox::core::MAX_STRATA];
    let mut recovered_everywhere = true;

    for &fraction in &[0.8, 0.4, 0.1] {
        let pipeline = PipelineBuilder::new()
            .engine(EngineKind::Pipelined)
            .sampler(SamplerKind::Oasrs)
            .budget(QueryBudget::SamplingFraction(fraction))
            .query(Query::TopK(10))
            .window(WindowConfig::paper_default())
            .seed(7)
            .build_native();
        let report = pipeline.run_items(&items).expect("pipeline run");

        for w in &report.windows {
            let exact = w.exact_per_stratum.as_ref().expect("exact counts");
            let top = w.result.top_k.as_ref().expect("top-k");
            let keys: Vec<u64> = top.iter().map(|&(k, _)| k).collect();
            for &s in &streamapprox::query::top_k_strata(exact, 3) {
                if !keys.contains(&(s as u64)) {
                    recovered_everywhere = false;
                    eprintln!(
                        "MISS: fraction {fraction}: true top-3 source {s} absent in \
                         window {}..{}",
                        w.start_ms, w.end_ms
                    );
                }
            }
        }
        let last = report.windows.last().expect("windows");
        per_fraction.push(last.result.top_k.clone().expect("top-k"));

        // exact counts of the same last window (identical across fractions)
        let last_span = (last.start_ms, last.end_ms);
        exact_counts.iter_mut().for_each(|c| *c = 0.0);
        for it in &items {
            if it.ts >= last_span.0 && it.ts < last_span.1 {
                exact_counts[it.stratum as usize] += 1.0;
            }
        }
    }

    let exact_ranked = streamapprox::query::top_k_strata(&exact_counts, 10);
    for rank in 0..10 {
        let cell = |f: usize| -> String {
            per_fraction[f]
                .get(rank)
                .map(|&(k, c)| format!("src{k:02} ({c:.0})"))
                .unwrap_or_else(|| "-".into())
        };
        let e = exact_ranked[rank];
        table.row(vec![
            format!("{}", rank + 1),
            cell(0),
            cell(1),
            cell(2),
            format!("src{e:02} ({})", exact_counts[e]),
        ]);
    }
    table.print();
    println!(
        "\ntrue top-3 recovered in every window at every fraction: {}",
        if recovered_everywhere { "YES" } else { "NO" }
    );
    assert!(recovered_everywhere, "acceptance: top-3 must always be recovered");

    // ---- Part 2: the sketch directly over 10k source IPs ------------------
    println!("\ndirect sketch: 10 000 synthetic source IPs, zipf(1.3), 500k flows");
    let mut rng = Rng::seed_from_u64(99);
    let popularity: Vec<f64> = (0..10_000).map(|i| 1.0 / (1.0 + i as f64).powf(1.3)).collect();
    // Synthetic 32-bit addresses; index 0 is the heaviest talker.
    let addr = |i: usize| 0x0A00_0000u64 + i as u64;

    for &fraction in &[0.8, 0.4, 0.1] {
        let mut hh = streamapprox::sketch::HeavyHitters::new(256, 2048, 4, 5);
        let weight = 1.0 / fraction; // HT weight of a Bernoulli(fraction) sample
        for _ in 0..500_000 {
            let src = rng.categorical(&popularity);
            if rng.bernoulli(fraction) {
                hh.offer(addr(src), weight);
            }
        }
        let top: Vec<String> = hh
            .top_k(5)
            .into_iter()
            .map(|(k, c)| format!("{:08x}:{:.0}", k, c))
            .collect();
        let head_ok = (0..3).all(|i| hh.top_k(10).iter().any(|&(k, _)| k == addr(i)));
        println!(
            "  fraction {:>4}: top-5 = [{}]  (±{:.0} over-bound; true top-3 in top-10: {})",
            format!("{}%", (fraction * 100.0) as u32),
            top.join(", "),
            hh.over_estimate_bound(),
            if head_ok { "yes" } else { "NO" }
        );
    }
}
