//! Network traffic analytics case study (paper §6.2): measure total TCP /
//! UDP / ICMP traffic per sliding window over a CAIDA-like NetFlow stream,
//! comparing StreamApprox against the Spark-style baselines.
//!
//! ```bash
//! make artifacts && cargo run --release --example network_traffic
//! ```

use streamapprox::datasets::caida::{CaidaConfig, ICMP, TCP, UDP};
use streamapprox::prelude::*;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let svc = match ComputeService::start(Backend::Xla, None) {
        Ok(s) => {
            println!("compute backend: XLA (AOT artifacts)");
            s
        }
        Err(e) => {
            println!("compute backend: native ({e})");
            ComputeService::native()
        }
    };

    // 60 s of synthetic backbone NetFlow (~1.2 M flows).
    let trace = CaidaConfig::default().generate(60_000);
    println!("replaying {} flow records", trace.len());

    let mut rows = Vec::new();
    for (name, engine, sampler) in [
        ("flink-streamapprox", EngineKind::Pipelined, SamplerKind::Oasrs),
        ("spark-streamapprox", EngineKind::Batched, SamplerKind::Oasrs),
        ("spark-srs", EngineKind::Batched, SamplerKind::Srs),
        ("spark-sts", EngineKind::Batched, SamplerKind::Sts),
        ("native-flink", EngineKind::Pipelined, SamplerKind::None),
    ] {
        let pipeline = PipelineBuilder::new()
            .engine(engine)
            .sampler(sampler)
            .budget(QueryBudget::SamplingFraction(0.6))
            .query(Query::PerStratumSum)
            .window(WindowConfig::paper_default())
            .workers(2)
            .build_with_handle(svc.handle());
        let r = pipeline.run_items(&trace)?;
        rows.push((name, r));
    }

    println!(
        "\n{:<20} {:>12} {:>10} {:>14}",
        "system", "items/s", "loss", "wall(ms)"
    );
    for (name, r) in &rows {
        println!(
            "{:<20} {:>12.0} {:>9.3}% {:>14.1}",
            name,
            r.throughput(),
            r.mean_accuracy_loss() * 100.0,
            r.wall_ns as f64 / 1e6
        );
    }

    // Show the per-protocol breakdown of the last full window of the
    // StreamApprox run.
    let (_, sa) = &rows[0];
    if let Some(w) = sa.windows.last() {
        let approx = w.result.per_stratum.as_ref().unwrap();
        let exact = w.exact_per_stratum.as_ref().unwrap();
        println!("\nlast window ({}-{} s) per-protocol bytes:", w.start_ms / 1000, w.end_ms / 1000);
        for (proto, name) in [(TCP, "TCP"), (UDP, "UDP"), (ICMP, "ICMP")] {
            let a = approx[proto as usize];
            let e = exact[proto as usize];
            println!(
                "  {:<5} approx {:>14.0}  exact {:>14.0}  loss {:>7.3}%",
                name,
                a,
                e,
                (a - e).abs() / e.max(1.0) * 100.0
            );
        }
    }
    Ok(())
}
