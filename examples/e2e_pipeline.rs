//! End-to-end driver: exercises **all layers composed** on a real small
//! workload, proving the full stack works — broker ingestion (Kafka-like
//! aggregator) → parallel OASRS sampling → both engines → sliding windows →
//! the AOT-compiled XLA aggregation artifacts (L2 JAX graph wrapping the L1
//! Pallas kernel) → error estimation + adaptive feedback.
//!
//! Reports the paper's headline metric — throughput speedup of the sampled
//! systems over native execution at a given accuracy — on the CAIDA-like
//! network workload.  Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use streamapprox::datasets::caida::CaidaConfig;
use streamapprox::prelude::*;
use streamapprox::stream::{Broker, ReplayTool, TopicConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---- Layer check 1: XLA artifacts must load (no native fallback: this
    // driver exists to prove the AOT path). -------------------------------
    let svc = ComputeService::start(Backend::Xla, None)
        .map_err(|e| format!("XLA artifacts required (run `make artifacts`): {e}"))?;
    println!("[1/4] XLA backend up: artifacts compiled on PJRT CPU");

    // ---- Layer check 2: broker ingestion. -------------------------------
    // 120 s of backbone NetFlow (~2.4 M flows), replayed through the
    // Kafka-like aggregator exactly as the paper's methodology describes
    // (200-item messages, §6.1).
    let trace = CaidaConfig { flows_per_sec: 20_000.0, ..Default::default() }.generate(120_000);
    let broker = Broker::new();
    broker.create_topic("netflow", TopicConfig { partitions: 4, capacity: 64 * 1024 })?;
    let replay = ReplayTool::new(trace.clone());
    let mut consumer = broker.consumer("netflow")?;
    let mut via_broker: Vec<Item> = Vec::with_capacity(trace.len());
    std::thread::scope(|s| -> Result<(), streamapprox::core::Error> {
        s.spawn(|| replay.replay_all(&broker, "netflow"));
        while let Some(it) = consumer.poll() {
            via_broker.push(it);
        }
        Ok(())
    })?;
    assert_eq!(via_broker.len(), trace.len(), "broker must conserve items");
    via_broker.sort_by_key(|i| i.ts);
    println!(
        "[2/4] broker delivered {} items ({} produced / {} consumed)",
        via_broker.len(),
        broker.stats("netflow")?.0,
        broker.stats("netflow")?.1
    );

    // ---- Layer check 3: all four systems over the same stream. ----------
    let window = WindowConfig::paper_default();
    let run = |engine: EngineKind, sampler: SamplerKind, budget: QueryBudget| {
        let p = PipelineBuilder::new()
            .engine(engine)
            .sampler(sampler)
            .budget(budget)
            .query(Query::PerStratumSum)
            .window(window)
            .workers(2)
            .build_with_handle(svc.handle());
        p.run_items(&via_broker)
    };

    let native = run(EngineKind::Pipelined, SamplerKind::None, QueryBudget::SamplingFraction(1.0))?;
    let native_b = run(EngineKind::Batched, SamplerKind::None, QueryBudget::SamplingFraction(1.0))?;
    let flink_sa =
        run(EngineKind::Pipelined, SamplerKind::Oasrs, QueryBudget::SamplingFraction(0.6))?;
    let spark_sa =
        run(EngineKind::Batched, SamplerKind::Oasrs, QueryBudget::SamplingFraction(0.6))?;
    println!("[3/4] four systems executed over the broker-fed stream");

    // ---- Layer check 4: headline metrics. -------------------------------
    let headline = |name: &str, r: &RunReport, base: &RunReport| {
        println!(
            "  {:<20} {:>10.0} items/s  ({:.2}x native)  loss {:.3}%  windows {}",
            name,
            r.throughput(),
            r.throughput() / base.throughput(),
            r.mean_accuracy_loss() * 100.0,
            r.windows.len()
        );
    };
    println!("[4/4] headline (sampling fraction 60%):");
    headline("native-flink", &native, &native);
    headline("flink-streamapprox", &flink_sa, &native);
    headline("native-spark", &native_b, &native_b);
    headline("spark-streamapprox", &spark_sa, &native_b);

    let speedup_flink = flink_sa.throughput() / native.throughput();
    let speedup_spark = spark_sa.throughput() / native_b.throughput();
    let loss = flink_sa.mean_accuracy_loss();
    println!(
        "\nheadline: Flink-SA {speedup_flink:.2}x native-Flink, Spark-SA {speedup_spark:.2}x native-Spark, loss {:.3}%",
        loss * 100.0
    );

    // The e2e driver is also a gate: sampling must beat native while
    // keeping the paper-grade accuracy.
    assert!(speedup_flink > 1.0, "Flink StreamApprox must beat native Flink");
    assert!(speedup_spark > 1.0, "Spark StreamApprox must beat native Spark");
    // Heavy-tailed flow sizes put a floor under sampling error at 10^5-item
    // windows; 2% is paper-grade for this workload scale.
    assert!(loss < 0.02, "accuracy loss must stay under 2% at 60% sampling");
    assert!(flink_sa.windows.len() >= 20, "must emit full window series");
    println!("\nE2E OK — all layers composed (broker → OASRS → engines → XLA → bounds)");
    Ok(())
}
