//! Quickstart: approximate SUM over a three-sub-stream Gaussian mix with
//! OASRS at a 60% budget, printing each window's `output ± bound` next to
//! the exact value.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use streamapprox::prelude::*;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Prefer the AOT XLA artifacts; fall back to the native executor.
    let pipeline = PipelineBuilder::new()
        .engine(EngineKind::Pipelined)
        .sampler(SamplerKind::Oasrs)
        .budget(QueryBudget::SamplingFraction(0.6))
        .query(Query::Sum)
        .window(WindowConfig::paper_default()) // w = 10 s, δ = 5 s
        .workers(2);
    let pipeline = match pipeline.clone().build_xla() {
        Ok(p) => {
            println!("compute backend: XLA (AOT artifacts)");
            p
        }
        Err(e) => {
            println!("compute backend: native ({e})");
            pipeline.build_native()
        }
    };

    // 60 s of the paper's §5.1 Gaussian microbenchmark mix.
    let stream = StreamConfig::gaussian_micro(1000.0, 7);
    let report = pipeline.run_stream(&stream, 60_000)?;

    println!(
        "processed {} items in {:.1} ms  ({:.0} items/s)",
        report.items_processed,
        report.wall_ns as f64 / 1e6,
        report.throughput()
    );
    println!("{:<12} {:>24} {:>16} {:>10}", "window", "approx SUM ± bound(95%)", "exact SUM", "loss");
    for w in &report.windows {
        let ci = w.result.scalar.unwrap();
        println!(
            "{:>6}-{:<5} {:>15.0} ±{:>7.0} {:>16.0} {:>9.3}%",
            w.start_ms / 1000,
            w.end_ms / 1000,
            ci.value,
            ci.bound,
            w.exact_scalar.unwrap_or(f64::NAN),
            w.accuracy_loss().unwrap_or(f64::NAN) * 100.0
        );
    }
    println!("mean accuracy loss: {:.4}%", report.mean_accuracy_loss() * 100.0);
    Ok(())
}
