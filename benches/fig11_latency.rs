//! Bench: regenerate paper Fig. 11 — total processing latency of both
//! case-study datasets at 60% sampling.

use streamapprox::harness::{figures, Ctx, Scale};

fn main() {
    let scale = match std::env::var("SA_SCALE").as_deref() {
        Ok("full") => Scale::full(),
        _ => Scale::quick(),
    };
    let ctx = Ctx::auto(scale);
    eprintln!("backend: {:?}, scale: {:?}", ctx.backend(), ctx.scale);
    figures::fig11(&ctx).print();
}
