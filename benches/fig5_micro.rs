//! Bench: regenerate paper Fig. 5 (a) throughput vs sampling fraction,
//! (b) accuracy loss vs sampling fraction, (c) throughput vs batch interval.
//!
//! `cargo bench --bench fig5_micro` (env `SA_SCALE=full` for the recorded
//! EXPERIMENTS.md scale).

use streamapprox::harness::{figures, Ctx, Scale};

fn main() {
    let scale = match std::env::var("SA_SCALE").as_deref() {
        Ok("full") => Scale::full(),
        _ => Scale::quick(),
    };
    let ctx = Ctx::auto(scale);
    eprintln!("backend: {:?}, scale: {:?}", ctx.backend(), ctx.scale);
    figures::fig5a(&ctx).print();
    figures::fig5b(&ctx).print();
    figures::fig5c(&ctx).print();
}
