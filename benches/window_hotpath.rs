//! Micro-benchmark of the window assembly hot path: per-slide merge cost of
//! the incremental pane store vs the seed's merge-all-intervals fold, at
//! window/slide ratios {4, 16, 64} with the slide (= pane) held fixed.
//!
//! The acceptance property (ISSUE 4): per-slide merge cost grows with the
//! panes *evicted*, not with the window/slide *ratio* — flat across ratios
//! at a fixed slide — while the merge-all reference degrades linearly in
//! the ratio.  Three instruments:
//!
//! * `pane-store` — `WindowAssembler::push_interval_view` (deque
//!   append/drain + ring-order meta fold, zero-copy emission);
//! * `merge-all` — the seed's path, reconstructed from the public API:
//!   clone every pane in the ring and `merge_worker_results` per slide;
//! * `sketch-panes` — `PaneStore<QuantileSketch>` (two-stacks): per-slide
//!   pane-sketch build + push + span aggregate, plus the *deterministic*
//!   structural-merge counter, which is the noise-free flatness witness
//!   (amortized ≤ 2 merges/slide at every ratio).
//!
//! A second section validates **quantile merge drift** (ISSUE 5): at pane
//! ratios {64, 256, 1024} the two-stacks store's merged span sketch is
//! compared against the exact rank of the span's raw values; BENCH_CHECK
//! asserts the observed rank error stays within the sketch's *reported*
//! `eps()` — the honest, depth-aware bound the bounded-drift compaction
//! discipline maintains.
//!
//! Knobs: `BENCH_SMOKE=1` (reduced iterations, side JSON) and
//! `BENCH_CHECK=1` (self-contained flatness/contrast assertions; exits
//! non-zero on violation).  Emits `BENCH_window_hotpath.json`.

use std::collections::VecDeque;
use std::time::Instant;

use streamapprox::sampling::oasrs::merge_worker_results;
use streamapprox::sampling::SampleResult;
use streamapprox::sketch::QuantileSketch;
use streamapprox::util::json::{obj, Value};
use streamapprox::util::rng::Rng;
use streamapprox::util::table::Table;
use streamapprox::window::{ExactAgg, PaneStore, WindowAssembler, WindowConfig};

const JSON_PATH: &str = "BENCH_window_hotpath.json";
const SMOKE_JSON_PATH: &str = "BENCH_window_hotpath.smoke.json";
const SLIDE_MS: u64 = 1_000;
const RATIOS: [usize; 3] = [4, 16, 64];
/// Long-window ratios for the quantile-drift validation (the regime the
/// ROADMAP flagged as unprofiled for cluster-quality drift).
const DRIFT_RATIOS: [usize; 3] = [64, 256, 1024];
/// Quantiles probed by the drift check.
const DRIFT_QS: [f64; 6] = [0.05, 0.25, 0.5, 0.75, 0.95, 0.99];

/// Deterministic pane stream: every pane carries `items_per_pane` sampled
/// items over 3 strata plus matching counters/ground truth.
fn mk_panes(n: usize, items_per_pane: usize, seed: u64) -> Vec<(SampleResult, ExactAgg)> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let mut r = SampleResult::default();
            let mut e = ExactAgg::default();
            for _ in 0..items_per_pane {
                let s = rng.range_usize(0, 3) as u16;
                let v = rng.normal(100.0, 10.0);
                r.sample.push((s, v));
                e.add(s, v);
            }
            for s in 0..3 {
                r.state.c[s] = (items_per_pane as f64 / 3.0).ceil() * 2.0;
                r.state.n_cap[s] = (items_per_pane as f64 / 3.0).ceil();
            }
            (r, e)
        })
        .collect()
}

/// ns/slide through the incremental assembler (pane clone included, same as
/// the reference, so the columns compare merge strategies, not allocators).
fn bench_pane_store(panes: &[(SampleResult, ExactAgg)], ratio: usize) -> f64 {
    let mut asm =
        WindowAssembler::new(WindowConfig::new(SLIDE_MS * ratio as u64, SLIDE_MS));
    let mut sink = 0usize;
    let t0 = Instant::now();
    for (r, e) in panes {
        if let Some(v) = asm.push_interval_view(r.clone(), *e) {
            sink += v.sample_len() + v.exact.total_count() as usize;
        }
    }
    let ns = t0.elapsed().as_nanos() as f64 / panes.len() as f64;
    assert!(sink > 0, "views must emit");
    ns
}

/// ns/slide through the seed's merge-all fold over the same ring.
fn bench_merge_all(panes: &[(SampleResult, ExactAgg)], ratio: usize) -> f64 {
    let mut ring: VecDeque<(SampleResult, ExactAgg)> = VecDeque::with_capacity(ratio);
    let mut sink = 0usize;
    let t0 = Instant::now();
    for (r, e) in panes {
        if ring.len() == ratio {
            ring.pop_front();
        }
        ring.push_back((r.clone(), *e));
        let merged = merge_worker_results(ring.iter().map(|(x, _)| x.clone()).collect());
        let mut exact = ExactAgg::default();
        for (_, pe) in &ring {
            exact.merge(pe);
        }
        sink += merged.sample.len() + exact.total_count() as usize;
    }
    let ns = t0.elapsed().as_nanos() as f64 / panes.len() as f64;
    assert!(sink > 0);
    ns
}

/// (ns/slide, structural merges/slide) for pane-level quantile sketches
/// through the two-stacks store: build the pane sketch, push, aggregate.
fn bench_sketch_panes(panes: &[(SampleResult, ExactAgg)], ratio: usize) -> (f64, f64) {
    let mut store: PaneStore<QuantileSketch> = PaneStore::new(ratio);
    let mut sink = 0usize;
    let t0 = Instant::now();
    for (r, _) in panes {
        let mut sk = QuantileSketch::new(200);
        for &(_, v) in &r.sample {
            sk.offer(v, 1.0);
        }
        store.push(sk);
        if let Some(agg) = store.aggregate() {
            sink += agg.n_clusters();
        }
    }
    let ns = t0.elapsed().as_nanos() as f64 / panes.len() as f64;
    assert!(sink > 0);
    (ns, store.merge_ops() as f64 / panes.len() as f64)
}

struct Row {
    ratio: usize,
    pane_ns: f64,
    mergeall_ns: f64,
    sketch_ns: f64,
    sketch_ops: f64,
}

struct DriftRow {
    ratio: usize,
    max_rank_err: f64,
    reported_eps: f64,
    merge_depth: u32,
}

/// Drive `ratio + ratio/2` panes of heavy-tailed values through a
/// two-stacks quantile-pane store and measure the merged span sketch's
/// worst rank error against the exact values of the final window span.
fn bench_quantile_drift(ratio: usize, per_pane: usize, seed: u64) -> DriftRow {
    let mut rng = Rng::seed_from_u64(seed);
    let mut store: PaneStore<QuantileSketch> = PaneStore::new(ratio);
    let mut window_vals: VecDeque<Vec<f64>> = VecDeque::with_capacity(ratio + 1);
    for _ in 0..(ratio + ratio / 2) {
        let mut sk = QuantileSketch::new(200);
        let mut vals = Vec::with_capacity(per_pane);
        for _ in 0..per_pane {
            let v = rng.log_normal(6.9, 1.5);
            sk.offer(v, 1.0);
            vals.push(v);
        }
        store.push(sk);
        window_vals.push_back(vals);
        if window_vals.len() > ratio {
            window_vals.pop_front();
        }
    }
    let agg = store.aggregate().expect("non-empty span");
    let mut all: Vec<f64> = window_vals.iter().flatten().copied().collect();
    all.sort_by(|a, b| a.partial_cmp(b).expect("finite values"));
    let mut max_err = 0.0f64;
    for &q in &DRIFT_QS {
        let v = agg.quantile(q);
        let rank = all.partition_point(|&x| x <= v) as f64 / all.len() as f64;
        max_err = max_err.max((rank - q).abs());
    }
    DriftRow {
        ratio,
        max_rank_err: max_err,
        reported_eps: agg.eps(),
        merge_depth: agg.merge_depth(),
    }
}

fn check_drift(rows: &[DriftRow]) -> bool {
    let mut ok = true;
    for r in rows {
        if r.max_rank_err > r.reported_eps {
            eprintln!(
                "drift check FAILED: ratio {}: observed rank error {:.4} exceeds reported \
                 eps {:.4} (merge depth {})",
                r.ratio, r.max_rank_err, r.reported_eps, r.merge_depth
            );
            ok = false;
        }
    }
    if ok {
        let last = rows.last().expect("rows");
        eprintln!(
            "drift ok: worst rank error {:.4} <= reported eps {:.4} at ratio {} \
             (merge depth {})",
            last.max_rank_err, last.reported_eps, last.ratio, last.merge_depth
        );
    }
    ok
}

fn check_flatness(rows: &[Row]) -> bool {
    let mut ok = true;
    let r4 = &rows[0];
    let r64 = rows.last().expect("rows");
    // Deterministic witness: two-stacks structural merges per slide are
    // amortized ≤ 2 at every ratio (the seed pays `ratio` merges).
    for r in rows {
        if r.sketch_ops > 2.0 {
            eprintln!(
                "flatness check FAILED: ratio {} does {:.2} pane merges/slide (> 2 amortized)",
                r.ratio, r.sketch_ops
            );
            ok = false;
        }
    }
    // Timing witnesses (generous bounds for noisy runners): the pane store
    // must stay within 8x of itself across a 16x ratio spread.  The bound
    // is not 1x because the window *footprint* grows with the ratio (a
    // ratio-64 window sample is ~0.5–2 MB and falls out of L1/L2), so the
    // per-slide append/drain writes into cold cache lines and the constant
    // drifts — a property of storing the span at all, paid far more
    // heavily by the merge-all path, which re-touches the whole footprint
    // every slide.  Items churned and merges per slide stay
    // ratio-independent; the ops witness above is the exact algorithmic
    // check, this one catches gross regressions…
    if r64.pane_ns > 8.0 * r4.pane_ns {
        eprintln!(
            "flatness check FAILED: pane-store {:.0} ns/slide at ratio 64 vs {:.0} at ratio 4",
            r64.pane_ns, r4.pane_ns
        );
        ok = false;
    }
    // …while the merge-all reference must show its linear degradation and
    // lose clearly to the pane store at the top ratio.
    if r64.mergeall_ns < 4.0 * r4.mergeall_ns {
        eprintln!(
            "contrast check FAILED: merge-all {:.0} ns/slide at ratio 64 vs {:.0} at ratio 4 \
             (expected ~16x growth)",
            r64.mergeall_ns, r4.mergeall_ns
        );
        ok = false;
    }
    if r64.pane_ns * 2.0 > r64.mergeall_ns {
        eprintln!(
            "contrast check FAILED: pane-store {:.0} ns/slide not clearly ahead of merge-all \
             {:.0} at ratio 64",
            r64.pane_ns, r64.mergeall_ns
        );
        ok = false;
    }
    if ok {
        eprintln!(
            "flatness ok: pane-store {:.0} -> {:.0} ns/slide across ratios 4 -> 64 \
             (merge-all {:.0} -> {:.0}); sketch merges/slide {:.2} -> {:.2}",
            r4.pane_ns, r64.pane_ns, r4.mergeall_ns, r64.mergeall_ns, r4.sketch_ops,
            r64.sketch_ops
        );
    }
    ok
}

fn write_json(
    path: &str,
    rows: &[Row],
    drift: &[DriftRow],
    mode: &str,
    items_per_pane: usize,
    intervals: usize,
) {
    let ratios = Value::Obj(
        rows.iter()
            .map(|r| {
                (
                    format!("{}", r.ratio),
                    obj(vec![
                        ("pane_store_ns_per_slide", Value::Num(r.pane_ns)),
                        ("merge_all_ns_per_slide", Value::Num(r.mergeall_ns)),
                        ("sketch_panes_ns_per_slide", Value::Num(r.sketch_ns)),
                        ("sketch_merge_ops_per_slide", Value::Num(r.sketch_ops)),
                    ]),
                )
            })
            .collect(),
    );
    let drift_obj = Value::Obj(
        drift
            .iter()
            .map(|r| {
                (
                    format!("{}", r.ratio),
                    obj(vec![
                        ("max_rank_err", Value::Num(r.max_rank_err)),
                        ("reported_eps", Value::Num(r.reported_eps)),
                        ("merge_depth", Value::Num(r.merge_depth as f64)),
                    ]),
                )
            })
            .collect(),
    );
    let doc = obj(vec![
        ("bench", Value::Str("window_hotpath".into())),
        ("provenance", Value::Str("cargo-bench".into())),
        ("mode", Value::Str(mode.into())),
        ("slide_ms", Value::Num(SLIDE_MS as f64)),
        ("items_per_pane", Value::Num(items_per_pane as f64)),
        ("intervals", Value::Num(intervals as f64)),
        ("ratios", ratios),
        ("quantile_drift", drift_obj),
    ]);
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let smoke =
        std::env::var("BENCH_SMOKE").is_ok() || std::env::args().any(|a| a == "--smoke");
    let check = std::env::var("BENCH_CHECK").is_ok();
    let (items_per_pane, intervals) = if smoke { (500, 160) } else { (2_000, 640) };

    let mut t = Table::new(
        format!(
            "window hot path ({items_per_pane} sampled items/pane, {intervals} slides, \
             slide fixed at {SLIDE_MS} ms)"
        ),
        &[
            "w/δ ratio",
            "pane-store (ns/slide)",
            "merge-all (ns/slide)",
            "sketch-panes (ns/slide)",
            "pane merges/slide",
        ],
    );
    let mut rows = Vec::new();
    for &ratio in &RATIOS {
        // fresh pane stream per ratio; warm-up = one full window span
        let panes = mk_panes(intervals + ratio, items_per_pane, 42 + ratio as u64);
        let pane_ns = bench_pane_store(&panes[..], ratio);
        let mergeall_ns = bench_merge_all(&panes[..], ratio);
        let (sketch_ns, sketch_ops) = bench_sketch_panes(&panes[..], ratio);
        t.row(vec![
            format!("{ratio}"),
            format!("{pane_ns:.0}"),
            format!("{mergeall_ns:.0}"),
            format!("{sketch_ns:.0}"),
            format!("{sketch_ops:.2}"),
        ]);
        rows.push(Row { ratio, pane_ns, mergeall_ns, sketch_ns, sketch_ops });
    }
    t.print();

    // Quantile merge drift at long-window ratios: the merged span sketch's
    // worst observed rank error vs its reported (depth-aware) eps.  Same
    // pane size in smoke and full mode — the drift sweep is cheap next to
    // the timing loops, and shrinking panes below the compaction buffer
    // (4c) would silently validate the raw-buffer path instead of the
    // summary-of-summaries path the check exists for.
    let drift_per_pane = 1_000;
    let mut dt = Table::new(
        format!(
            "quantile merge drift ({drift_per_pane} values/pane, lognormal, 200 clusters, \
             quantiles {DRIFT_QS:?})"
        ),
        &["w/δ ratio", "max rank err", "reported eps", "merge depth"],
    );
    let mut drift_rows = Vec::new();
    for &ratio in &DRIFT_RATIOS {
        let row = bench_quantile_drift(ratio, drift_per_pane, 7_000 + ratio as u64);
        dt.row(vec![
            format!("{ratio}"),
            format!("{:.4}", row.max_rank_err),
            format!("{:.4}", row.reported_eps),
            format!("{}", row.merge_depth),
        ]);
        drift_rows.push(row);
    }
    dt.print();

    let mut ok = if check { check_flatness(&rows) } else { true };
    if check {
        ok &= check_drift(&drift_rows);
    }
    if smoke {
        write_json(SMOKE_JSON_PATH, &rows, &drift_rows, "smoke", items_per_pane, intervals);
    } else if ok {
        write_json(JSON_PATH, &rows, &drift_rows, "full", items_per_pane, intervals);
    } else {
        eprintln!("flatness/drift check failed: leaving {JSON_PATH} untouched");
    }
    if !ok {
        std::process::exit(1);
    }
}
