//! Bench: regenerate paper Fig. 8 — the per-window MEAN timeline under
//! Gaussian skew for the three Spark-based sampled systems vs exact.

use streamapprox::harness::{figures, Ctx, Scale};

fn main() {
    let scale = match std::env::var("SA_SCALE").as_deref() {
        Ok("full") => Scale::full(),
        _ => Scale::quick(),
    };
    let ctx = Ctx::auto(scale);
    eprintln!("backend: {:?}, scale: {:?}", ctx.backend(), ctx.scale);
    figures::fig8(&ctx).print();
}
