//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! 1. **OASRS allocation policy** — equal split per stratum (the paper's
//!    "fixed-size reservoir per sub-stream") vs proportional-to-arrivals.
//!    Run on the Poisson skew workload where the choice matters most.
//! 2. **Worker chunk size** — the shuffle-buffer granularity of §Perf
//!    optimization 1 (per-item sends at one extreme).
//! 3. **Feedback damping** — convergence speed vs overshoot of the adaptive
//!    accuracy budget.
//!
//! `cargo bench --bench ablations`

use streamapprox::budget::QueryBudget;
use streamapprox::error::estimator::{estimate, StrataPartials};
use streamapprox::pipeline::PipelineBuilder;
use streamapprox::prelude::*;
use streamapprox::sampling::{OasrsSampler, Sampler};
use streamapprox::stream::StreamGenerator;
use streamapprox::util::table::{fmt_pct, Table};

/// Ablation 1: equal vs proportional per-stratum allocation, measured as
/// accuracy loss on the Poisson long-tail workload at small fractions.
/// "Proportional" is emulated by running the estimator over a proportional
/// subsample built with the same reservoir machinery (per-stratum caps set
/// to fraction * C_i) — isolating the allocation policy from everything
/// else.
fn ablation_allocation() {
    let mut t = Table::new(
        "Ablation 1: OASRS allocation policy — accuracy loss, Gaussian skew (80/19/1)",
        &["fraction", "equal split (paper)", "proportional"],
    );
    for &fraction in &[0.01, 0.02, 0.05, 0.1] {
        let items = StreamGenerator::new(&StreamConfig::gaussian_skew(10_000.0, 91))
            .take_until(30_000);
        let exact: f64 = items.iter().map(|i| i.value).sum();

        // equal split: the real sampler (two passes so EWMA locks in)
        let mut eq = OasrsSampler::new(fraction, 7);
        for it in &items {
            eq.offer(it);
        }
        eq.finish_interval();
        for it in &items {
            eq.offer(it);
        }
        let r = eq.finish_interval();
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        let loss_eq = (est.sum - exact).abs() / exact;

        // proportional: same machinery, caps proportional to arrivals
        use streamapprox::core::MAX_STRATA;
        use streamapprox::sampling::Reservoir;
        let mut counts = [0f64; MAX_STRATA];
        for it in &items {
            counts[it.stratum as usize] += 1.0;
        }
        let mut reservoirs: Vec<Reservoir<f64>> = (0..MAX_STRATA)
            .map(|s| {
                Reservoir::new(((fraction * counts[s]).ceil() as usize).max(1), 7 + s as u64)
            })
            .collect();
        for it in &items {
            reservoirs[it.stratum as usize].offer(it.value);
        }
        let mut partials = StrataPartials::default();
        let mut state = streamapprox::error::estimator::StrataState::default();
        for s in 0..MAX_STRATA {
            state.c[s] = counts[s];
            state.n_cap[s] = reservoirs[s].capacity() as f64;
            for &v in reservoirs[s].items() {
                partials.push(s, v);
            }
        }
        let est_p = estimate(&partials, &state);
        let loss_prop = (est_p.sum - exact).abs() / exact;

        t.row(vec![fmt_pct(fraction), fmt_pct(loss_eq), fmt_pct(loss_prop)]);
    }
    t.print();
    println!(
        "(equal split gives the rare high-variance sub-stream C as many samples as\n\
         the dominant ones; proportional allocation starves it — the paper's\n\
         rationale for fixed-size per-stratum reservoirs)\n"
    );
}

/// Ablation 2: worker shuffle-buffer size (per-item sends = chunk 1).
/// Exercised through the real engine path by sweeping worker counts at the
/// fixed built-in chunk, plus the documented before/after of §Perf opt 1.
fn ablation_chunking() {
    let mut t = Table::new(
        "Ablation 2: pipelined OASRS @60% — workers sweep (chunked shuffle, single-core host)",
        &["workers", "throughput (items/s)"],
    );
    let items =
        StreamGenerator::new(&StreamConfig::gaussian_micro(1000.0, 92)).take_until(30_000);
    for &w in &[1usize, 2, 4, 8] {
        let p = PipelineBuilder::new()
            .engine(EngineKind::Pipelined)
            .sampler(SamplerKind::Oasrs)
            .budget(QueryBudget::SamplingFraction(0.6))
            .window(WindowConfig::paper_default())
            .workers(w)
            .track_exact(false)
            .build_native();
        let thr = (0..2)
            .map(|_| p.run_items(&items).unwrap().throughput())
            .fold(0.0f64, f64::max);
        t.row(vec![format!("{w}"), format!("{thr:.0}")]);
    }
    t.print();
    println!(
        "(see EXPERIMENTS.md §Perf #1: with per-item sends this table sat flat at\n\
         ~1.5M items/s for every configuration)\n"
    );
}

/// Ablation 3: feedback damping — windows to converge to a 1% target from a
/// 10x-too-small fraction, on a simulated error plant.
fn ablation_damping() {
    let mut t = Table::new(
        "Ablation 3: adaptive-budget damping — windows to reach 1% target (plant: err = 0.004/sqrt(f))",
        &["damping", "windows to target", "fraction overshoot"],
    );
    for &damping in &[0.25, 0.5, 1.0] {
        let mut c = streamapprox::error::feedback::FeedbackController::new(0.01, 0.016)
            .with_damping(damping);
        let mut f = c.fraction();
        let mut converged_at = None;
        let mut max_f: f64 = 0.0;
        for win in 0..40 {
            let err = 0.004 / f.sqrt();
            // damped controllers approach the target asymptotically; count
            // "converged" at within 5% of target
            if err <= 0.01 * 1.05 && converged_at.is_none() {
                converged_at = Some(win);
            }
            f = c.observe(err);
            max_f = max_f.max(f);
        }
        let fixed_point = (0.004f64 / 0.01).powi(2); // 0.16
        t.row(vec![
            format!("{damping}"),
            converged_at.map(|w| w.to_string()).unwrap_or("-".into()),
            fmt_pct((max_f - fixed_point).max(0.0) / fixed_point),
        ]);
    }
    t.print();
}

fn main() {
    ablation_allocation();
    ablation_chunking();
    ablation_damping();
}
