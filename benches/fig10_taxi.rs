//! Bench: regenerate paper Fig. 10 — the NYC-taxi case study (synthetic
//! DEBS'15-like rides; per-borough mean trip distance).

use streamapprox::harness::{figures, Ctx, Scale};

fn main() {
    let scale = match std::env::var("SA_SCALE").as_deref() {
        Ok("full") => Scale::full(),
        _ => Scale::quick(),
    };
    let ctx = Ctx::auto(scale);
    eprintln!("backend: {:?}, scale: {:?}", ctx.backend(), ctx.scale);
    let (a, b, c) = figures::fig10(&ctx);
    a.print();
    b.print();
    c.print();
}
