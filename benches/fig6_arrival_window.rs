//! Bench: regenerate paper Fig. 6 (a) accuracy vs sub-stream-C arrival
//! rate, (b) throughput vs window size, (c) accuracy vs window size.

use streamapprox::harness::{figures, Ctx, Scale};

fn main() {
    let scale = match std::env::var("SA_SCALE").as_deref() {
        Ok("full") => Scale::full(),
        _ => Scale::quick(),
    };
    let ctx = Ctx::auto(scale);
    eprintln!("backend: {:?}, scale: {:?}", ctx.backend(), ctx.scale);
    figures::fig6a(&ctx).print();
    let (b, c) = figures::fig6bc(&ctx);
    b.print();
    c.print();
}
