//! Bench: regenerate paper Fig. 9 — the network-traffic case study
//! (CAIDA-like synthetic NetFlow; per-protocol traffic totals).

use streamapprox::harness::{figures, Ctx, Scale};

fn main() {
    let scale = match std::env::var("SA_SCALE").as_deref() {
        Ok("full") => Scale::full(),
        _ => Scale::quick(),
    };
    let ctx = Ctx::auto(scale);
    eprintln!("backend: {:?}, scale: {:?}", ctx.backend(), ctx.scale);
    let (a, b, c) = figures::fig9(&ctx);
    a.print();
    b.print();
    c.print();
}
