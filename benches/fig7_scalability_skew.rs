//! Bench: regenerate paper Fig. 7 (a) scalability over workers/nodes,
//! (b) throughput at fixed 1% accuracy under Gaussian skew, (c) accuracy
//! under Poisson skew.

use streamapprox::harness::{figures, Ctx, Scale};

fn main() {
    let scale = match std::env::var("SA_SCALE").as_deref() {
        Ok("full") => Scale::full(),
        _ => Scale::quick(),
    };
    let ctx = Ctx::auto(scale);
    eprintln!("backend: {:?}, scale: {:?}", ctx.backend(), ctx.scale);
    figures::fig7a(&ctx).print();
    figures::fig7b(&ctx).print();
    figures::fig7c(&ctx).print();
}
