//! Micro-benchmark of the sketch hot paths: per-item offer cost, merge
//! cost, and query cost for the three mergeable summaries.  This is the
//! §Perf instrument for the sketch subsystem — run before/after
//! optimizations and record deltas in EXPERIMENTS.md §Perf.
//!
//! `cargo bench --bench sketch_hotpath` (env `SA_SKETCH_N=5000000` to scale).

use std::time::Instant;

use streamapprox::sketch::{HeavyHitters, HyperLogLog, QuantileSketch};
use streamapprox::util::rng::Rng;
use streamapprox::util::table::Table;

struct Timing {
    offer_ns: f64,
    merge_us: f64,
    query_us: f64,
}

fn bench<S, O, M, Q>(n: usize, mut mk: impl FnMut(u64) -> S, offer: O, merge: M, query: Q) -> Timing
where
    O: Fn(&mut S, f64, f64),
    M: Fn(&mut S, &S),
    Q: Fn(&S) -> f64,
{
    let mut rng = Rng::seed_from_u64(1);
    let vals: Vec<(f64, f64)> =
        (0..n).map(|_| (rng.log_normal(6.9, 1.5), rng.range_f64(0.5, 4.0))).collect();

    // offer
    let mut s = mk(1);
    let t0 = Instant::now();
    for &(v, w) in &vals {
        offer(&mut s, v, w);
    }
    let offer_ns = t0.elapsed().as_nanos() as f64 / n as f64;

    // merge (8 shards, like the per-window shard merge)
    let shards: Vec<S> = (0..8)
        .map(|i| {
            let mut p = mk(2 + i);
            for &(v, w) in vals.iter().skip(i as usize).step_by(8) {
                offer(&mut p, v, w);
            }
            p
        })
        .collect();
    let mut merged = mk(99);
    let t0 = Instant::now();
    for p in &shards {
        merge(&mut merged, p);
    }
    let merge_us = t0.elapsed().as_nanos() as f64 / 1e3;

    // query
    let t0 = Instant::now();
    let mut acc = 0.0;
    for _ in 0..100 {
        acc += query(&merged);
    }
    assert!(acc.is_finite() || acc.is_nan());
    let query_us = t0.elapsed().as_nanos() as f64 / 100.0 / 1e3;

    Timing { offer_ns, merge_us, query_us }
}

fn main() {
    let n: usize = std::env::var("SA_SKETCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);

    let mut t = Table::new(
        format!("sketch hot path (n = {n}, lognormal values, HT-style weights)"),
        &["sketch", "offer ns/item", "merge-8 us", "query us"],
    );

    let q = bench(
        n,
        |_| QuantileSketch::new(200),
        |s, v, w| s.offer(v, w),
        |a, b| a.merge(b),
        |s| s.quantile(0.95),
    );
    t.row(vec![
        "quantile (c=200)".into(),
        format!("{:.1}", q.offer_ns),
        format!("{:.1}", q.merge_us),
        format!("{:.2}", q.query_us),
    ]);

    let h = bench(
        n,
        |_| HyperLogLog::new(12),
        |s, v, _| s.offer(v),
        |a, b| a.merge(b),
        |s| s.estimate(),
    );
    t.row(vec![
        "hyperloglog (p=12)".into(),
        format!("{:.1}", h.offer_ns),
        format!("{:.1}", h.merge_us),
        format!("{:.2}", h.query_us),
    ]);

    let hh = bench(
        n,
        |_| HeavyHitters::new(64, 1024, 4, 7),
        |s, v, w| s.offer((v as u64) % 1024, w),
        |a, b| a.merge(b),
        |s| s.top_k(10).first().map(|&(_, c)| c).unwrap_or(0.0),
    );
    t.row(vec![
        "heavy-hitters (cm 1024x4)".into(),
        format!("{:.1}", hh.offer_ns),
        format!("{:.1}", hh.merge_us),
        format!("{:.2}", hh.query_us),
    ]);

    t.print();
}
