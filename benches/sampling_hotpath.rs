//! Micro-benchmark of the sampling hot path: per-item cost of each
//! algorithm at ingest, and the per-interval close cost.  This is the §Perf
//! instrument for L3 — run before/after optimizations and record deltas in
//! EXPERIMENTS.md §Perf.
//!
//! Besides the ASCII table it emits `BENCH_sampling_hotpath.json` (same
//! numbers, machine-readable) so future PRs have a perf trajectory to
//! compare against.  Knobs, all optional:
//!
//! * `BENCH_SMOKE=1` (or `--smoke`) — reduced iterations for CI.
//! * `BENCH_CHECK=1` — before overwriting the JSON, compare against the
//!   committed baseline and **exit non-zero if the OASRS per-item cost
//!   regressed more than 3×** (a generous bound that tolerates CI noise
//!   but catches accidental hot-path regressions).  Baselines whose
//!   `provenance` is not `cargo-bench` (e.g. the bootstrap estimate
//!   committed from an environment without a Rust toolchain) are skipped.
//!   `BENCH_CHECK` also enforces the **columnar contrast gate**: the SoA
//!   ingest path (`…+col` rows) must be ≥2× faster per item than the
//!   scalar path for OASRS and SRS at f ∈ {0.1, 0.01} — a within-run
//!   ratio, so it holds on any machine regardless of baseline provenance.

use std::time::Instant;

use streamapprox::core::{ColumnarChunk, Item};
use streamapprox::engine::IngestPool;
use streamapprox::sampling::SamplerKind;
use streamapprox::util::json::{obj, parse, Value};
use streamapprox::util::rng::Rng;
use streamapprox::util::table::Table;

const JSON_PATH: &str = "BENCH_sampling_hotpath.json";
/// Smoke runs write here instead, so reduced-iteration numbers can never
/// overwrite the committed full-run baseline.
const SMOKE_JSON_PATH: &str = "BENCH_sampling_hotpath.smoke.json";
/// Regression bound for `BENCH_CHECK`: fail when per-item cost exceeds
/// baseline × 3.
const REGRESSION_FACTOR: f64 = 3.0;

fn bench_sampler(
    kind: SamplerKind,
    fraction: f64,
    n_items: usize,
    intervals: usize,
    columnar: bool,
) -> (f64, f64) {
    let mut pool = IngestPool::new(kind, 1, fraction, 7);
    let mut rng = Rng::seed_from_u64(1);
    let items: Vec<Item> = (0..n_items)
        .map(|i| Item::new((rng.range_usize(0, 3)) as u16, rng.normal(100.0, 10.0), i as u64))
        .collect();
    // Pre-transposed outside the timed loop: the engines stage each
    // interval's slice into a reused chunk once, so the timed region here
    // measures the kernels, not the transpose.
    let chunk = ColumnarChunk::from_items(&items);

    // warm-up interval (locks OASRS capacities)
    if columnar {
        pool.offer_columnar(&chunk);
    } else {
        pool.offer_slice(&items);
    }
    pool.finish_interval();

    let t0 = Instant::now();
    let mut close_ns = 0u64;
    for _ in 0..intervals {
        if columnar {
            pool.offer_columnar(&chunk);
        } else {
            pool.offer_slice(&items);
        }
        let c0 = Instant::now();
        let r = pool.finish_interval();
        close_ns += c0.elapsed().as_nanos() as u64;
        assert!(r.arrived() > 0.0);
    }
    let total_ns = t0.elapsed().as_nanos() as f64;
    let per_item_ns = (total_ns - close_ns as f64) / (n_items * intervals) as f64;
    let close_ms = close_ns as f64 / intervals as f64 / 1e6;
    (per_item_ns, close_ms)
}

/// Within-run columnar speedup gate: scalar / columnar per-item cost must
/// be at least this for the guarded (sampler, fraction) pairs.  Overridable
/// via `BENCH_CONTRAST_MIN` (e.g. while tuning kernels on a new machine)
/// without editing the bench.
const MIN_COLUMNAR_CONTRAST: f64 = 2.0;

/// The `BENCH_CHECK` columnar contrast gate (ISSUE 7 acceptance): both
/// paths ran in this process seconds apart, so the ratio is insensitive to
/// machine speed and baseline provenance.
fn check_columnar_contrast(results: &[(String, f64, f64)]) -> bool {
    let min_contrast = std::env::var("BENCH_CONTRAST_MIN")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(MIN_COLUMNAR_CONTRAST);
    let guarded = ["Oasrs@f0.1", "Oasrs@f0.01", "Srs@f0.1", "Srs@f0.01"];
    let lookup =
        |label: &str| results.iter().find(|(l, _, _)| l == label).map(|(_, p, _)| *p);
    let mut ok = true;
    for base in guarded {
        let col_label = format!("{base}+col");
        match (lookup(base), lookup(&col_label)) {
            (Some(scalar), Some(col)) => {
                let ratio = scalar / col;
                if ratio < min_contrast {
                    eprintln!(
                        "columnar contrast FAILED: {base} {scalar:.2} ns/item vs \
                         {col_label} {col:.2} ns/item = {ratio:.2}x < \
                         {min_contrast}x"
                    );
                    ok = false;
                } else {
                    eprintln!(
                        "columnar contrast ok: {base} {scalar:.2} ns/item vs \
                         {col_label} {col:.2} ns/item = {ratio:.2}x (gate {min_contrast}x)"
                    );
                }
            }
            _ => {
                eprintln!("columnar contrast FAILED: rows missing for {base}");
                ok = false;
            }
        }
    }
    ok
}

/// Compare fresh results against the committed baseline (if any); returns
/// `false` on a regression beyond [`REGRESSION_FACTOR`].
fn check_baseline(results: &[(String, f64, f64)]) -> bool {
    let Ok(text) = std::fs::read_to_string(JSON_PATH) else {
        eprintln!("perf check: no committed baseline at {JSON_PATH}; skipping");
        return true;
    };
    let Ok(baseline) = parse(&text) else {
        eprintln!("perf check: unparsable baseline at {JSON_PATH}; skipping");
        return true;
    };
    if baseline.get("provenance").and_then(|v| v.as_str()) != Some("cargo-bench") {
        eprintln!("perf check: baseline provenance is not cargo-bench; skipping");
        return true;
    }
    let mut ok = true;
    for (label, per_item_ns, _) in results {
        // Only the OASRS rows are guarded — the paper's contribution is the
        // one whose hot path this repo optimizes; the other samplers have
        // intentionally expensive baseline cost signatures.
        if !label.starts_with("Oasrs") {
            continue;
        }
        let base = baseline
            .get("samplers")
            .and_then(|s| s.get(label))
            .and_then(|s| s.get("per_item_ns"))
            .and_then(|v| v.as_f64());
        let Some(base) = base else { continue };
        if *per_item_ns > base * REGRESSION_FACTOR {
            eprintln!(
                "perf check FAILED: {label} per-item {per_item_ns:.1} ns > \
                 {REGRESSION_FACTOR}x baseline {base:.1} ns"
            );
            ok = false;
        } else {
            eprintln!(
                "perf check ok: {label} per-item {per_item_ns:.1} ns vs baseline {base:.1} ns"
            );
        }
    }
    ok
}

fn write_json(
    path: &str,
    results: &[(String, f64, f64)],
    mode: &str,
    n: usize,
    intervals: usize,
) {
    let samplers = Value::Obj(
        results
            .iter()
            .map(|(label, per_item_ns, close_ms)| {
                (
                    label.clone(),
                    obj(vec![
                        ("per_item_ns", Value::Num(*per_item_ns)),
                        ("close_ms", Value::Num(*close_ms)),
                    ]),
                )
            })
            .collect(),
    );
    let doc = obj(vec![
        ("bench", Value::Str("sampling_hotpath".into())),
        ("provenance", Value::Str("cargo-bench".into())),
        ("mode", Value::Str(mode.into())),
        ("n_items", Value::Num(n as f64)),
        ("intervals", Value::Num(intervals as f64)),
        ("workers", Value::Num(1.0)),
        ("samplers", samplers),
    ]);
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok()
        || std::env::args().any(|a| a == "--smoke");
    let check = std::env::var("BENCH_CHECK").is_ok();
    let (n, intervals) = if smoke { (20_000, 2) } else { (200_000, 5) };

    // (label, kind, fraction).  The Oasrs@f0.1 row is the acceptance
    // metric for the slice-based ingest path; Oasrs@f0.01 is the regime
    // where per-stratum streams run ~100x their reservoir capacity, so the
    // Algorithm-L geometric skips engage and the per-item cost collapses
    // to a decrement (see EXPERIMENTS.md §Perf for the regime analysis).
    // The Srs low-fraction rows exist for the columnar contrast gate.
    let configs: Vec<(&str, SamplerKind, f64)> = vec![
        ("Oasrs", SamplerKind::Oasrs, 0.4),
        ("Oasrs@f0.1", SamplerKind::Oasrs, 0.1),
        ("Oasrs@f0.01", SamplerKind::Oasrs, 0.01),
        ("Srs", SamplerKind::Srs, 0.4),
        ("Srs@f0.1", SamplerKind::Srs, 0.1),
        ("Srs@f0.01", SamplerKind::Srs, 0.01),
        ("Sts", SamplerKind::Sts, 0.4),
        ("WeightedRes", SamplerKind::WeightedRes, 0.4),
        ("None", SamplerKind::None, 0.4),
    ];

    let mut t = Table::new(
        format!("sampling hot path ({n} items/interval, {intervals} intervals, 1 worker)"),
        &["sampler", "fraction", "per-item (ns)", "interval close (ms)"],
    );
    let mut results: Vec<(String, f64, f64)> = Vec::new();
    // Interleaved scalar/columnar rows per config, so drift (thermal,
    // cache) hits both sides of every contrast pair equally.
    for (label, kind, fraction) in configs {
        for columnar in [false, true] {
            let row_label =
                if columnar { format!("{label}+col") } else { label.to_string() };
            let (per_item, close) = bench_sampler(kind, fraction, n, intervals, columnar);
            t.row(vec![
                row_label.clone(),
                format!("{fraction}"),
                format!("{per_item:.1}"),
                format!("{close:.2}"),
            ]);
            results.push((row_label, per_item, close));
        }
    }

    // Observability-overhead rows: the same OASRS hot path with the metrics
    // registry enabled vs disabled (tracing stays off in both — its default).
    // This bench is its own process, so toggling the process-global flag is
    // safe here (library tests must never do this).  Labels deliberately do
    // NOT start with "Oasrs": the baseline regression guard above keys on
    // that prefix and these rows measure the obs plane, not the sampler.
    // Interleaved on/off pairs so drift (thermal, cache) hits both equally.
    let (mut on_item, mut on_close, mut off_item, mut off_close) = (0.0, 0.0, 0.0, 0.0);
    let rounds = if smoke { 1 } else { 3 };
    for _ in 0..rounds {
        streamapprox::obs::set_metrics_enabled(true);
        let (a, b) = bench_sampler(SamplerKind::Oasrs, 0.1, n, intervals, true);
        streamapprox::obs::set_metrics_enabled(false);
        let (c, d) = bench_sampler(SamplerKind::Oasrs, 0.1, n, intervals, true);
        on_item += a / rounds as f64;
        on_close += b / rounds as f64;
        off_item += c / rounds as f64;
        off_close += d / rounds as f64;
    }
    streamapprox::obs::set_metrics_enabled(true);
    for (label, item, close) in [
        ("ObsOn (Oasrs 10%)", on_item, on_close),
        ("ObsOff (Oasrs 10%)", off_item, off_close),
    ] {
        t.row(vec![
            label.to_string(),
            "0.1".to_string(),
            format!("{item:.1}"),
            format!("{close:.2}"),
        ]);
        results.push((label.to_string(), item, close));
    }
    t.print();

    let mut ok = if check { check_baseline(&results) } else { true };
    if check && !check_columnar_contrast(&results) {
        ok = false;
    }
    if check {
        // Instrumentation-overhead gate: registry-enabled per-item cost must
        // stay within 5% of the uninstrumented path (+0.5 ns absolute slack
        // so sub-ns timer noise cannot fail a ~2 ns measurement).
        let budget = off_item * 1.05 + 0.5;
        if on_item > budget {
            eprintln!(
                "obs overhead check FAILED: instrumented {on_item:.2} ns/item > \
                 5% budget over uninstrumented {off_item:.2} ns/item"
            );
            ok = false;
        } else {
            eprintln!(
                "obs overhead check ok: instrumented {on_item:.2} ns/item vs \
                 uninstrumented {off_item:.2} ns/item"
            );
        }
    }
    // Smoke numbers go to a side file and a failed regression check never
    // overwrites the baseline — otherwise the next run would compare
    // against the very numbers that just failed.
    if smoke {
        write_json(SMOKE_JSON_PATH, &results, "smoke", n, intervals);
    } else if ok {
        write_json(JSON_PATH, &results, "full", n, intervals);
    } else {
        eprintln!("regression check failed: leaving {JSON_PATH} untouched");
    }
    if !ok {
        std::process::exit(1);
    }
}
