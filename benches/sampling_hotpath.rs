//! Micro-benchmark of the sampling hot path: per-item cost of each
//! algorithm at ingest, and the per-interval close cost.  This is the §Perf
//! instrument for L3 — run before/after optimizations and record deltas in
//! EXPERIMENTS.md §Perf.

use std::time::Instant;

use streamapprox::core::Item;
use streamapprox::engine::IngestPool;
use streamapprox::sampling::SamplerKind;
use streamapprox::util::rng::Rng;
use streamapprox::util::table::Table;

fn bench_sampler(kind: SamplerKind, n_items: usize, intervals: usize) -> (f64, f64) {
    let mut pool = IngestPool::new(kind, 1, 0.4, 7);
    let mut rng = Rng::seed_from_u64(1);
    let items: Vec<Item> = (0..n_items)
        .map(|i| Item::new((rng.range_usize(0, 3)) as u16, rng.normal(100.0, 10.0), i as u64))
        .collect();

    // warm-up interval (locks OASRS capacities)
    for &it in &items {
        pool.offer(it);
    }
    pool.finish_interval();

    let t0 = Instant::now();
    let mut close_ns = 0u64;
    for _ in 0..intervals {
        for &it in &items {
            pool.offer(it);
        }
        let c0 = Instant::now();
        let r = pool.finish_interval();
        close_ns += c0.elapsed().as_nanos() as u64;
        assert!(r.arrived() > 0.0);
    }
    let total_ns = t0.elapsed().as_nanos() as f64;
    let per_item_ns = (total_ns - close_ns as f64) / (n_items * intervals) as f64;
    let close_ms = close_ns as f64 / intervals as f64 / 1e6;
    (per_item_ns, close_ms)
}

fn main() {
    let n = 200_000;
    let intervals = 5;
    let mut t = Table::new(
        format!("sampling hot path ({n} items/interval, {intervals} intervals, 1 worker)"),
        &["sampler", "per-item (ns)", "interval close (ms)"],
    );
    for kind in [SamplerKind::Oasrs, SamplerKind::Srs, SamplerKind::Sts, SamplerKind::None] {
        let (per_item, close) = bench_sampler(kind, n, intervals);
        t.row(vec![
            format!("{kind:?}"),
            format!("{per_item:.1}"),
            format!("{close:.2}"),
        ]);
    }
    t.print();
}
